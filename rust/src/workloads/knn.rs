//! K-Nearest-Neighbours classification [FH89] — neighbour-based workload.
//!
//! Tree-accelerated exact kNN: scikit-learn's profile builds a K-D tree,
//! mlpack's a binary-space tree (paper Section IV). Every query descends
//! the tree (node loads feeding split branches) and scans leaves through
//! the permuted index array — the canonical `A[B[i]]` irregular pattern.
//! The query loop honours [`RunContext::visit_order`] and the leaf scans
//! carry the Section V-C software-prefetch hooks. Quality metric:
//! leave-one-out-style training accuracy.

use super::kdtree::{TraceTree, TreeKind};
use super::{Category, LibraryProfile, RunContext, RunResult, Workload};
use crate::data::{make_blobs, Dataset};
use crate::trace::{AddressSpace, Recorder};

/// KNN workload.
pub struct Knn {
    pub k: usize,
    pub leaf_size: usize,
    /// Software-prefetch lookahead distance in leaf entries (0 = off;
    /// the recorder's `sw_prefetch_enabled` flag gates actual emission).
    pub lookahead: usize,
}

impl Default for Knn {
    fn default() -> Self {
        Self { k: 5, leaf_size: 30, lookahead: 8 }
    }
}

pub(crate) fn tree_kind(profile: LibraryProfile) -> TreeKind {
    match profile {
        LibraryProfile::Sklearn => TreeKind::KdTree,
        LibraryProfile::Mlpack => TreeKind::BallTree,
    }
}

impl Workload for Knn {
    fn name(&self) -> &'static str {
        "KNN"
    }

    fn category(&self) -> Category {
        Category::NeighbourBased
    }

    fn supports_visit_order(&self) -> bool {
        true
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_blobs(rows, features, 6, 1.5, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let n = ds.n_samples();
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("knn.x", n, ds.n_features());
        let tree = TraceTree::build(
            &ds.x,
            r_x,
            &mut space,
            tree_kind(ctx.profile),
            self.leaf_size,
            rec,
        );

        let default_order: Vec<usize> = (0..n).collect();
        let order = ctx.visit_order.as_deref().unwrap_or(&default_order);
        assert_eq!(order.len(), n, "visit order must cover all samples");

        let n_classes = ds.n_classes.max(2);
        let mut votes = vec![0usize; n_classes];
        let mut correct = 0usize;
        for &qi in order {
            rec.load_row(r_x, qi, ds.n_features());
            // k+1 because the query point finds itself first
            let neigh = tree.knn(&ds.x, ds.x.row(qi), self.k + 1, rec, self.lookahead);
            votes.iter_mut().for_each(|v| *v = 0);
            for &(_, r) in neigh.iter().skip(1) {
                let label = ds.y[r as usize] as usize;
                votes[label.min(n_classes - 1)] += 1;
            }
            let pred = votes
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(c, _)| c)
                .unwrap_or(0);
            if pred == ds.y[qi] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        RunResult {
            quality: acc,
            detail: format!("LOO accuracy {acc:.4}, k={}, {} nodes", self.k, tree.n_nodes()),
        }
    }

    fn first_touch_order(&self, ds: &Dataset, ctx: &RunContext) -> Vec<usize> {
        // inspector: the tree's leaf order is the order queries touch rows
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("knn.x", ds.n_samples(), ds.n_features());
        let mut sink = crate::trace::NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let tree = TraceTree::build(
            &ds.x,
            r_x,
            &mut space,
            tree_kind(ctx.profile),
            self.leaf_size,
            &mut rec,
        );
        tree.leaf_order().iter().map(|&i| i as usize).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{InstructionMix, NullSink, VecSink};

    #[test]
    fn knn_classifies_blobs() {
        let w = Knn { k: 5, leaf_size: 16, lookahead: 0 };
        let ds = w.make_dataset(800, 6, 28);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext::default(), &mut rec);
        assert!(res.quality > 0.9, "accuracy {} ({})", res.quality, res.detail);
    }

    #[test]
    fn both_profiles_agree_on_accuracy() {
        let w = Knn { k: 3, leaf_size: 20, lookahead: 0 };
        let ds = w.make_dataset(500, 5, 29);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let sk = w.run(&ds, &RunContext::with_profile(LibraryProfile::Sklearn), &mut rec);
        let ml = w.run(&ds, &RunContext::with_profile(LibraryProfile::Mlpack), &mut rec);
        // exact search ⇒ identical predictions regardless of tree kind
        assert!((sk.quality - ml.quality).abs() < 1e-12);
    }

    #[test]
    fn trace_has_irregular_indirect_loads_and_branches() {
        let w = Knn { k: 3, leaf_size: 16, lookahead: 0 };
        let ds = w.make_dataset(400, 5, 30);
        let mut mix = InstructionMix::default();
        {
            let mut rec = Recorder::new(&mut mix, 0);
            w.run(&ds, &RunContext::default(), &mut rec);
        }
        // paper Fig. 5: neighbour workloads are branchy (~20%)
        assert!(mix.branch_fraction() > 0.10, "{}", mix.branch_fraction());
        assert!(mix.conditional_branch_fraction() > 0.8);
    }

    #[test]
    fn first_touch_order_is_permutation() {
        let w = Knn::default();
        let ds = w.make_dataset(300, 5, 31);
        let mut ft = w.first_touch_order(&ds, &RunContext::default());
        ft.sort_unstable();
        assert_eq!(ft, (0..300).collect::<Vec<_>>());
    }

    #[test]
    fn lookahead_prefetches_when_enabled() {
        let w = Knn { k: 3, leaf_size: 16, lookahead: 6 };
        let ds = w.make_dataset(300, 5, 32);
        let mut sink = VecSink::default();
        {
            let mut rec = Recorder::new(&mut sink, 0);
            rec.sw_prefetch_enabled = true;
            w.run(&ds, &RunContext::default(), &mut rec);
        }
        let n_pf = sink
            .events
            .iter()
            .filter(|e| matches!(e, crate::trace::Event::SwPrefetch { .. }))
            .count();
        assert!(n_pf > 100, "expected prefetch stream, got {n_pf}");
    }
}
