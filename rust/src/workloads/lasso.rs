//! Lasso regression [Tib96] — matrix-based workload.
//!
//! Coordinate descent, scikit-learn's `Lasso` algorithm. Like sklearn
//! (which requires Fortran-ordered arrays for `coordinate_descent`), the
//! instrumented implementation works on a **feature-major copy** of the
//! dataset so that each coordinate update streams one contiguous column.
//! The trace is therefore regular/streaming like the other matrix
//! workloads, with two column passes per coordinate update.

use super::ridge::r_squared;
use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_regression, Dataset};
use crate::trace::{AddressSpace, Recorder};

const SITE_CHANGED: u32 = 1;

/// Lasso workload. Quality metric: training R².
pub struct Lasso {
    /// L1 penalty.
    pub alpha: f64,
}

impl Default for Lasso {
    fn default() -> Self {
        Self { alpha: 0.1 }
    }
}

fn soft_threshold(x: f64, t: f64) -> f64 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

impl Workload for Lasso {
    fn name(&self) -> &'static str {
        "Lasso"
    }

    fn category(&self) -> Category {
        Category::MatrixBased
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        // half the true coefficients are zero → Lasso's selection matters
        make_regression(rows, features, (features / 2).max(1), 5.0, seed).0
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let (n, m) = (ds.n_samples(), ds.n_features());
        let overhead = ctx.profile.loop_overhead_uops();
        // Fortran-order copy: column j occupies a contiguous n-vector.
        let mut cols: Vec<Vec<f64>> = vec![vec![0.0; n]; m];
        for i in 0..n {
            for j in 0..m {
                cols[j][i] = ds.x[(i, j)];
            }
        }
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("lasso.x", n, m); // row-major source
        let r_xt = space.alloc_matrix("lasso.xT", m, n); // feature-major copy
        let r_res = space.alloc_f64("lasso.residual", n);
        // trace the one-time layout conversion (np.asfortranarray):
        // stream the source rows, scatter-store into the columns
        for i in 0..n {
            rec.load_row(r_x, i, m);
            for j in 0..m {
                rec.store(r_xt.f64(j * n + i), 8);
            }
        }

        let col_sq: Vec<f64> = cols.iter().map(|c| c.iter().map(|v| v * v).sum()).collect();
        let mut w = vec![0.0; m];
        let mut residual: Vec<f64> = ds.y.clone();
        let alpha_n = self.alpha * n as f64;

        for _epoch in 0..ctx.iterations.max(1) {
            for j in 0..m {
                // rho = x_j · r + w_j ||x_j||² : one streaming column pass
                let col_base = j * n;
                rec.load(r_xt.f64(col_base), (n * 8).min(u32::MAX as usize) as u32);
                rec.load(r_res.f64(0), (n * 8).min(u32::MAX as usize) as u32);
                let _ = overhead;
                rec.profile_tick();
                rec.compute(1, (2 * n) as u32);
                rec.loop_branch(2, (n / 8).max(1) as u32);
                let col = &cols[j];
                let mut rho = 0.0;
                for i in 0..n {
                    rho += col[i] * residual[i];
                }
                rho += w[j] * col_sq[j];
                let w_new = if col_sq[j] > 0.0 {
                    soft_threshold(rho, alpha_n) / col_sq[j]
                } else {
                    0.0
                };
                let delta = w[j] - w_new;
                // residual update only when the coefficient moved
                // (sklearn's `if w_j != w_j_old` fast path)
                if rec.fcmp_branch(SITE_CHANGED, delta != 0.0) {
                    rec.load(r_xt.f64(col_base), (n * 8).min(u32::MAX as usize) as u32);
                    rec.store(r_res.f64(0), (n * 8).min(u32::MAX as usize) as u32);
                    rec.compute(overhead, (2 * n) as u32);
                    for i in 0..n {
                        residual[i] += delta * col[i];
                    }
                }
                w[j] = w_new;
            }
        }
        let r2 = r_squared(&ds.x, &ds.y, &w);
        let nnz = w.iter().filter(|v| v.abs() > 1e-12).count();
        RunResult { quality: r2, detail: format!("R²={r2:.4}, {nnz}/{m} nonzero") }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn lasso_fits_and_is_sparse() {
        let w = Lasso { alpha: 2.0 };
        let ds = w.make_dataset(1500, 10, 8);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext { iterations: 20, ..Default::default() }, &mut rec);
        assert!(res.quality > 0.9, "R² {} ({})", res.quality, res.detail);
    }

    #[test]
    fn large_alpha_zeroes_everything() {
        let w = Lasso { alpha: 1e7 };
        let ds = w.make_dataset(400, 6, 9);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext::default(), &mut rec);
        assert!(res.detail.contains("0/6 nonzero"), "{}", res.detail);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(5.0, 2.0), 3.0);
        assert_eq!(soft_threshold(-5.0, 2.0), -3.0);
        assert_eq!(soft_threshold(1.0, 2.0), 0.0);
    }

    #[test]
    fn column_streaming_trace() {
        let w = Lasso::default();
        let ds = w.make_dataset(300, 5, 10);
        let mut mix = crate::trace::InstructionMix::default();
        {
            let mut rec = Recorder::new(&mut mix, 0);
            w.run(&ds, &RunContext { iterations: 2, ..Default::default() }, &mut rec);
        }
        assert!(mix.branch_fraction() < 0.05);
        assert!(mix.bytes_loaded > (300 * 5 * 8) as u64, "streams columns repeatedly");
    }
}
