//! Latent Dirichlet Allocation [BNJ03] — matrix-based workload.
//!
//! Batch variational EM, the algorithm behind scikit-learn's
//! `LatentDirichletAllocation` (mlpack has none — paper Section II).
//! Each E-step sweeps the document-term matrix row by row (streaming row
//! loads + dense FP on the per-doc variational updates), the M-step
//! re-normalizes topic-word counts: the classic matrix-workload profile.
//! Quality metric: mean per-word log-likelihood (rises as topics fit).

use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_documents, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::stats::logsumexp;
use crate::util::Pcg64;

/// LDA workload.
pub struct Lda {
    pub n_topics: usize,
    /// Per-document variational sub-iterations.
    pub e_iters: usize,
    /// Dirichlet hyper-parameters.
    pub alpha: f64,
    pub eta: f64,
}

impl Default for Lda {
    fn default() -> Self {
        Self { n_topics: 5, e_iters: 8, alpha: 0.1, eta: 0.01 }
    }
}

/// Digamma via the standard shift + asymptotic expansion.
pub(crate) fn digamma(mut x: f64) -> f64 {
    let mut result = 0.0;
    while x < 6.0 {
        result -= 1.0 / x;
        x += 1.0;
    }
    let inv = 1.0 / x;
    let inv2 = inv * inv;
    result + x.ln() - 0.5 * inv
        - inv2 * (1.0 / 12.0 - inv2 * (1.0 / 120.0 - inv2 / 252.0))
}

impl Workload for Lda {
    fn name(&self) -> &'static str {
        "LDA"
    }

    fn category(&self) -> Category {
        Category::MatrixBased
    }

    fn in_mlpack(&self) -> bool {
        false
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        // features = vocabulary size; ~60 words per document
        make_documents(rows, features.max(4), self.n_topics, 60, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let (n, v) = (ds.n_samples(), ds.n_features());
        let k = self.n_topics;
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("lda.counts", n, v);
        let r_beta = space.alloc_matrix("lda.beta", k, v);
        let r_gamma = space.alloc_matrix("lda.gamma", n, k);
        let overhead = ctx.profile.loop_overhead_uops();

        // topic-word distributions (rows sum to 1), random init
        let mut rng = Pcg64::new(ctx.seed);
        let mut beta: Vec<Vec<f64>> = (0..k).map(|_| rng.dirichlet(1.0, v)).collect();
        let mut gamma = vec![vec![1.0 + self.alpha; k]; n];

        for _em in 0..ctx.iterations.max(1) {
            let mut beta_acc = vec![vec![self.eta; v]; k];
            for d in 0..n {
                rec.load_row(r_x, d, v);
                rec.load_row(r_gamma, d, k);
                let counts = ds.x.row(d);
                // per-doc variational loop
                for _ in 0..self.e_iters {
                    let _ = overhead;
                    rec.profile_tick();
                    rec.compute(2, (v * k * 4) as u32);
                    rec.loop_branch(1, (v / 4).max(1) as u32);
                    let e_theta: Vec<f64> = {
                        let dg_sum = digamma(gamma[d].iter().sum::<f64>());
                        gamma[d].iter().map(|&g| digamma(g) - dg_sum).collect()
                    };
                    let mut new_gamma = vec![self.alpha; k];
                    for w in 0..v {
                        let c = counts[w];
                        if c == 0.0 {
                            continue;
                        }
                        // phi_w ∝ beta[.,w] * exp(E[log theta])
                        let logs: Vec<f64> = (0..k)
                            .map(|t| beta[t][w].max(1e-300).ln() + e_theta[t])
                            .collect();
                        let z = logsumexp(&logs);
                        for t in 0..k {
                            new_gamma[t] += c * (logs[t] - z).exp();
                        }
                    }
                    gamma[d] = new_gamma;
                }
                rec.store_row(r_gamma, d, k);
                // accumulate expected topic-word counts for the M-step
                rec.compute(0, (v * k * 2) as u32);
                let dg_sum = digamma(gamma[d].iter().sum::<f64>());
                let e_theta: Vec<f64> =
                    gamma[d].iter().map(|&g| digamma(g) - dg_sum).collect();
                for w in 0..v {
                    let c = counts[w];
                    if c == 0.0 {
                        continue;
                    }
                    let logs: Vec<f64> = (0..k)
                        .map(|t| beta[t][w].max(1e-300).ln() + e_theta[t])
                        .collect();
                    let z = logsumexp(&logs);
                    for t in 0..k {
                        beta_acc[t][w] += c * (logs[t] - z).exp();
                    }
                }
            }
            // M-step: normalize topics
            rec.load(r_beta.at(0), (k * v * 8) as u32);
            rec.store(r_beta.at(0), (k * v * 8) as u32);
            rec.compute(0, (k * v * 2) as u32);
            for t in 0..k {
                let s: f64 = beta_acc[t].iter().sum();
                for w in 0..v {
                    beta[t][w] = beta_acc[t][w] / s;
                }
            }
        }

        // mean per-word log likelihood under the fitted doc mixtures
        let mut ll = 0.0;
        let mut words = 0.0;
        for d in 0..n {
            let gsum: f64 = gamma[d].iter().sum();
            let theta: Vec<f64> = gamma[d].iter().map(|g| g / gsum).collect();
            for w in 0..v {
                let c = ds.x[(d, w)];
                if c == 0.0 {
                    continue;
                }
                let p: f64 = (0..k).map(|t| theta[t] * beta[t][w]).sum();
                ll += c * p.max(1e-300).ln();
                words += c;
            }
        }
        let per_word = ll / words.max(1.0);
        RunResult {
            quality: per_word,
            detail: format!("per-word log-lik {per_word:.4}, {k} topics"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn digamma_matches_known_values() {
        // digamma(1) = -gamma_E
        assert!((digamma(1.0) + 0.5772156649).abs() < 1e-8);
        // recurrence digamma(x+1) = digamma(x) + 1/x
        for &x in &[0.5, 2.3, 7.7] {
            assert!((digamma(x + 1.0) - digamma(x) - 1.0 / x).abs() < 1e-9);
        }
    }

    #[test]
    fn lda_beats_uniform_model() {
        let w = Lda::default();
        let ds = w.make_dataset(120, 30, 18);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext { iterations: 4, ..Default::default() }, &mut rec);
        let uniform_ll = (1.0 / 30.0f64).ln();
        assert!(
            res.quality > uniform_ll + 0.1,
            "LDA {} vs uniform {uniform_ll}",
            res.quality
        );
    }

    #[test]
    fn more_em_iterations_do_not_hurt() {
        let w = Lda::default();
        let ds = w.make_dataset(80, 20, 19);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let q1 = w.run(&ds, &RunContext { iterations: 1, ..Default::default() }, &mut rec).quality;
        let q5 = w.run(&ds, &RunContext { iterations: 5, ..Default::default() }, &mut rec).quality;
        assert!(q5 >= q1 - 0.05, "{q1} -> {q5}");
    }
}
