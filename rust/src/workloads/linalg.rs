//! Instrumented dense linear-algebra kernels.
//!
//! The matrix-based workloads spend their time in BLAS-style routines
//! over the dataset ("the memory accesses are regular ... the memory
//! access stalls may be due to the inability of the underlying BLAS
//! library to fully reuse the caches" — paper Section IV; the machine's
//! BLAS is the unblocked Netlib reference, Section II). These kernels are
//! real computations over the dataset matrix that emit the corresponding
//! streaming trace: row-sized loads, dense FP uops, loop branches.

use crate::trace::{Recorder, Region};
use crate::util::Matrix;

use super::ns;

const SITE_ROW_LOOP: u32 = 1;

/// C += Xᵀ X over the rows of `x` (SYRK by rank-1 updates, streaming row
/// by row as the Netlib reference does). Returns the M×M Gram matrix.
pub fn syrk(x: &Matrix, r_x: Region, rec: &mut Recorder) -> Matrix {
    let (n, m) = (x.rows(), x.cols());
    let mut c = Matrix::zeros(m, m);
    for i in 0..n {
        rec.load_row(r_x, i, m);
        // rank-1 update: m*(m+1)/2 FMAs on the symmetric half
        rec.compute(2, (m * (m + 1)) as u32);
        rec.loop_branch(SITE_ROW_LOOP + 8, ((m * m) / 8).max(1) as u32);
        rec.jump(ns::LINALG << 4 | SITE_ROW_LOOP);
        let row = x.row(i);
        for a in 0..m {
            let xa = row[a];
            for b in a..m {
                c[(a, b)] += xa * row[b];
            }
        }
    }
    // mirror the lower triangle
    for a in 0..m {
        for b in 0..a {
            c[(a, b)] = c[(b, a)];
        }
    }
    rec.compute((m * m) as u32 / 2, 0);
    c
}

/// y_out = X w (GEMV), streaming the rows of X.
pub fn gemv(x: &Matrix, r_x: Region, w: &[f64], rec: &mut Recorder) -> Vec<f64> {
    let (n, m) = (x.rows(), x.cols());
    assert_eq!(w.len(), m);
    let mut out = vec![0.0; n];
    for i in 0..n {
        rec.load_row(r_x, i, m);
        rec.compute(1, (2 * m) as u32);
        rec.loop_branch(SITE_ROW_LOOP + 9, (m / 4).max(1) as u32);
        let mut s = 0.0;
        let row = x.row(i);
        for j in 0..m {
            s += row[j] * w[j];
        }
        out[i] = s;
    }
    out
}

/// Xᵀ v over rows (the transpose product used by normal equations and
/// coordinate descent residual updates).
pub fn xt_v(x: &Matrix, r_x: Region, r_v: Region, v: &[f64], rec: &mut Recorder) -> Vec<f64> {
    let (n, m) = (x.rows(), x.cols());
    assert_eq!(v.len(), n);
    let mut out = vec![0.0; m];
    for i in 0..n {
        rec.load_row(r_x, i, m);
        rec.load_f64(r_v, i);
        rec.compute(1, (2 * m) as u32);
        rec.loop_branch(SITE_ROW_LOOP + 10, (m / 4).max(1) as u32);
        let row = x.row(i);
        for j in 0..m {
            out[j] += row[j] * v[i];
        }
    }
    out
}

/// In-place Cholesky solve of the small SPD system `a x = b` with its
/// (dense but tiny) trace. Panics if `a` is not SPD — matrix workloads
/// regularize before calling.
pub fn chol_solve(a: &Matrix, b: &[f64], r_a: Region, rec: &mut Recorder) -> Vec<f64> {
    let m = a.rows();
    // O(m^3/3) FP ops over an in-cache m×m panel
    rec.load(r_a.at(0), (m * m * 8) as u32);
    rec.compute((m * m) as u32, (m * m * m) as u32 / 3);
    crate::util::solve_spd(a, b).expect("matrix must be SPD (regularize first)")
}

/// Streamed squared-distance row: d_j = ||q - X_j||² for all rows j of a
/// block — the kernel of SVM-RBF's K(q, ·) computation.
pub fn sqdist_row(
    x: &Matrix,
    r_x: Region,
    q: &[f64],
    out: &mut [f64],
    rec: &mut Recorder,
) {
    let (n, m) = (x.rows(), x.cols());
    assert_eq!(out.len(), n);
    for i in 0..n {
        rec.load_row(r_x, i, m);
        rec.compute(1, (3 * m) as u32);
        rec.loop_branch(SITE_ROW_LOOP + 11, (m / 4).max(1) as u32);
        out[i] = crate::util::stats::sqdist(q, x.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{AddressSpace, NullSink, VecSink};

    fn setup(n: usize, m: usize) -> (Matrix, Region, AddressSpace) {
        let mut rng = crate::util::Pcg64::new(31);
        let mut x = Matrix::zeros(n, m);
        for v in x.as_mut_slice() {
            *v = rng.normal();
        }
        let mut space = AddressSpace::new();
        let r = space.alloc_matrix("x", n, m);
        (x, r, space)
    }

    #[test]
    fn syrk_matches_matmul() {
        let (x, r, _) = setup(50, 6);
        let mut s = NullSink;
        let mut rec = Recorder::new(&mut s, 1);
        let c = syrk(&x, r, &mut rec);
        let want = x.transpose().matmul(&x);
        for i in 0..6 {
            for j in 0..6 {
                assert!((c[(i, j)] - want[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn gemv_matches_reference() {
        let (x, r, _) = setup(40, 5);
        let w = vec![1.0, -2.0, 0.5, 3.0, 0.0];
        let mut s = NullSink;
        let mut rec = Recorder::new(&mut s, 1);
        let y = gemv(&x, r, &w, &mut rec);
        for i in 0..40 {
            let want: f64 = (0..5).map(|j| x[(i, j)] * w[j]).sum();
            assert!((y[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn xt_v_matches_reference() {
        let (x, r, mut space) = setup(30, 4);
        let rv = space.alloc_f64("v", 30);
        let v: Vec<f64> = (0..30).map(|i| i as f64 * 0.1).collect();
        let mut s = NullSink;
        let mut rec = Recorder::new(&mut s, 1);
        let got = xt_v(&x, r, rv, &v, &mut rec);
        for j in 0..4 {
            let want: f64 = (0..30).map(|i| x[(i, j)] * v[i]).sum();
            assert!((got[j] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn chol_solve_roundtrip() {
        let (x, r, _) = setup(30, 4);
        let mut a = x.transpose().matmul(&x);
        for i in 0..4 {
            a[(i, i)] += 1.0;
        }
        let truth = [0.5, -1.0, 2.0, 0.0];
        let b: Vec<f64> = (0..4)
            .map(|i| (0..4).map(|j| a[(i, j)] * truth[j]).sum())
            .collect();
        let mut s = NullSink;
        let mut rec = Recorder::new(&mut s, 1);
        let sol = chol_solve(&a, &b, r, &mut rec);
        for (got, want) in sol.iter().zip(truth.iter()) {
            assert!((got - want).abs() < 1e-9);
        }
    }

    #[test]
    fn sqdist_row_matches() {
        let (x, r, _) = setup(20, 3);
        let q = [0.1, 0.2, 0.3];
        let mut out = vec![0.0; 20];
        let mut s = NullSink;
        let mut rec = Recorder::new(&mut s, 1);
        sqdist_row(&x, r, &q, &mut out, &mut rec);
        for i in 0..20 {
            assert!((out[i] - crate::util::stats::sqdist(&q, x.row(i))).abs() < 1e-12);
        }
    }

    #[test]
    fn traces_are_streaming_row_loads() {
        let (x, r, _) = setup(100, 8);
        let mut sink = VecSink::default();
        {
            let mut rec = Recorder::new(&mut sink, 1);
            gemv(&x, r, &[0.0; 8], &mut rec);
        }
        // loads must be sequential full rows: addresses strictly ascending
        let mut loads = sink.events.iter().filter_map(|e| match e {
            crate::trace::Event::Load { addr, size, .. } => Some((*addr, *size)),
            _ => None,
        });
        let mut prev = 0;
        let mut count = 0;
        for (a, s) in loads.by_ref() {
            assert!(a >= prev, "non-streaming load");
            assert_eq!(s, 64, "row of 8 f64s");
            prev = a;
            count += 1;
        }
        assert_eq!(count, 100);
    }
}
