//! The paper's 13 traditional-ML workloads (Table I), instrumented.
//!
//! Each workload is a *real* implementation of the algorithm (it computes
//! correct models, verified by unit tests) that additionally emits the
//! micro-architectural event trace of its inner loops through a
//! [`Recorder`]. Two library profiles mirror the two implementations the
//! paper measures:
//!
//! - [`LibraryProfile::Sklearn`] — scikit-learn v1.0.x algorithmic
//!   choices (K-D tree neighbour search, Cython-style loop overhead,
//!   Fortran-order coordinate descent, ...).
//! - [`LibraryProfile::Mlpack`] — mlpack v3.4 choices (binary-space
//!   tree neighbour search, leaner C++ loops). Like the real library it
//!   implements no SVM-RBF, LDA or t-SNE.
//!
//! | Category        | Workloads |
//! |-----------------|-----------|
//! | Matrix-based    | Lasso, Ridge, PCA, Linear SVM, SVM-RBF, LDA |
//! | Neighbour-based | KMeans, GMM, KNN, DBSCAN, t-SNE |
//! | Tree-based      | Decision Tree, Random Forests, Adaboost |

pub mod adaboost;
pub mod dbscan;
pub mod dtree;
pub mod gmm;
pub mod kdtree;
pub mod kmeans;
pub mod knn;
pub mod lasso;
pub mod lda;
pub mod linalg;
pub mod pca;
pub mod rforest;
pub mod ridge;
pub mod svm;
pub mod tsne;

use crate::data::Dataset;
use crate::trace::Recorder;

/// Workload category (paper Table I).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    MatrixBased,
    NeighbourBased,
    TreeBased,
}

impl std::fmt::Display for Category {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Category::MatrixBased => write!(f, "matrix"),
            Category::NeighbourBased => write!(f, "neighbour"),
            Category::TreeBased => write!(f, "tree"),
        }
    }
}

/// Which library implementation's algorithmic choices to mirror.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LibraryProfile {
    Sklearn,
    Mlpack,
}

impl LibraryProfile {
    /// Extra integer uops per inner-loop iteration modelling the
    /// implementation overhead difference the paper observes (Cython
    /// generated C with bounds/refcount bookkeeping vs lean templated
    /// C++). Calibrated so the CPI gap between Figs. 1's sklearn and
    /// mlpack bars reproduces.
    pub fn loop_overhead_uops(self) -> u32 {
        match self {
            LibraryProfile::Sklearn => 4,
            LibraryProfile::Mlpack => 1,
        }
    }

    /// Whether this library profile implements `w` at all. scikit-learn
    /// covers every Table I workload; mlpack v3.4 ships no SVM-RBF, LDA
    /// or t-SNE (paper Section II), so those must be rejected up front
    /// rather than silently simulated under the wrong profile.
    pub fn implements(self, w: &dyn Workload) -> bool {
        match self {
            LibraryProfile::Sklearn => true,
            LibraryProfile::Mlpack => w.in_mlpack(),
        }
    }
}

/// Per-run options threaded to the workload.
#[derive(Debug, Clone)]
pub struct RunContext {
    /// Training iterations (the paper caps at 5).
    pub iterations: usize,
    /// RNG seed for any run-internal randomness (shuffles, init).
    pub seed: u64,
    pub profile: LibraryProfile,
    /// Optional computation reordering: the order in which per-sample
    /// outer loops visit samples (identity when `None`).
    pub visit_order: Option<Vec<usize>>,
}

impl Default for RunContext {
    fn default() -> Self {
        Self {
            iterations: 5,
            seed: 0x5eed,
            profile: LibraryProfile::Sklearn,
            visit_order: None,
        }
    }
}

impl RunContext {
    pub fn with_profile(profile: LibraryProfile) -> Self {
        Self { profile, ..Default::default() }
    }
}

/// Outcome of a traced training run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Workload-specific quality scalar (documented per workload:
    /// inertia, accuracy, R², log-likelihood, ...). Used by tests to
    /// assert the algorithm actually works, and by the reordering
    /// experiments to assert optimizations do not change results.
    pub quality: f64,
    /// Human-readable summary of the fitted model.
    pub detail: String,
}

/// A traced, instrumented traditional-ML workload.
pub trait Workload {
    /// Paper's workload name (e.g. "KMeans").
    fn name(&self) -> &'static str;

    fn category(&self) -> Category;

    /// Whether the mlpack profile implements this workload
    /// (mlpack lacks SVM-RBF, LDA and t-SNE — paper Section II).
    fn in_mlpack(&self) -> bool {
        true
    }

    /// Generate the canonical synthetic dataset for this workload at the
    /// given scale (the paper uses `sklearn.datasets` generators).
    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset;

    /// Train on `ds`, emitting the event trace into `rec`.
    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult;

    /// Row-visit order of the first training sweep (the inspector half of
    /// inspector–executor first-touch reordering). Default: sequential.
    fn first_touch_order(&self, ds: &Dataset, ctx: &RunContext) -> Vec<usize> {
        let _ = ctx;
        (0..ds.n_samples()).collect()
    }

    /// Whether the per-sample outer loop supports computation reordering
    /// (`RunContext::visit_order`). Tree-based ensemble workloads do not
    /// (paper Table IX: Z-order computation reordering "Not applicable").
    fn supports_visit_order(&self) -> bool {
        false
    }
}

/// All workloads, in the paper's Table I order.
pub fn registry() -> Vec<Box<dyn Workload>> {
    vec![
        Box::new(lasso::Lasso::default()),
        Box::new(ridge::Ridge::default()),
        Box::new(pca::Pca::default()),
        Box::new(svm::LinearSvm::default()),
        Box::new(svm::SvmRbf::default()),
        Box::new(lda::Lda::default()),
        Box::new(kmeans::KMeans::default()),
        Box::new(gmm::Gmm::default()),
        Box::new(knn::Knn::default()),
        Box::new(dbscan::Dbscan::default()),
        Box::new(tsne::Tsne::default()),
        Box::new(dtree::DecisionTree::default()),
        Box::new(rforest::RandomForest::default()),
        Box::new(adaboost::Adaboost::default()),
    ]
}

/// The workload names a library profile implements, in Table I order
/// (the valid `--workload` values under that `--profile`).
pub fn supported_names(profile: LibraryProfile) -> Vec<&'static str> {
    registry()
        .iter()
        .filter(|w| profile.implements(w.as_ref()))
        .map(|w| w.name())
        .collect()
}

/// Look a workload up by its (case-insensitive) paper name.
pub fn by_name(name: &str) -> Option<Box<dyn Workload>> {
    let lower = name.to_lowercase();
    registry().into_iter().find(|w| {
        w.name().to_lowercase() == lower
            || w.name().to_lowercase().replace([' ', '-'], "") == lower.replace([' ', '-'], "")
    })
}

/// The workloads the paper's multicore tables include (those with a
/// parallel implementation in the respective library): Tables III/IV.
pub fn multicore_names(profile: LibraryProfile) -> Vec<&'static str> {
    match profile {
        LibraryProfile::Sklearn => vec![
            "LDA", "GMM", "KMeans", "DBSCAN", "KNN", "t-SNE", "Random Forests", "Adaboost",
        ],
        LibraryProfile::Mlpack => {
            vec!["GMM", "KMeans", "DBSCAN", "KNN", "Random Forests", "Adaboost"]
        }
    }
}

/// Branch-site namespaces, one per workload (keeps gshare histories of
/// different workloads' sites from aliasing in cross-workload tests).
pub(crate) mod ns {
    pub const LASSO: u32 = 1;
    pub const RIDGE: u32 = 2;
    pub const PCA: u32 = 3;
    pub const LINSVM: u32 = 4;
    pub const SVMRBF: u32 = 5;
    pub const LDA: u32 = 6;
    pub const KMEANS: u32 = 7;
    pub const GMM: u32 = 8;
    pub const KNN: u32 = 9;
    pub const DBSCAN: u32 = 10;
    pub const TSNE: u32 = 11;
    pub const DTREE: u32 = 12;
    pub const RFOREST: u32 = 13;
    pub const ADABOOST: u32 = 14;
    pub const KDTREE: u32 = 15;
    pub const LINALG: u32 = 16;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_table_i() {
        let names: Vec<&str> = registry().iter().map(|w| w.name()).collect();
        for expect in [
            "Lasso", "Ridge", "PCA", "Linear SVM", "SVM-RBF", "LDA", "KMeans", "GMM", "KNN",
            "DBSCAN", "t-SNE", "Decision Tree", "Random Forests", "Adaboost",
        ] {
            assert!(names.contains(&expect), "missing {expect}");
        }
        assert_eq!(names.len(), 14);
    }

    #[test]
    fn categories_match_table_i() {
        for w in registry() {
            let expected = match w.name() {
                "Lasso" | "Ridge" | "PCA" | "Linear SVM" | "SVM-RBF" | "LDA" => {
                    Category::MatrixBased
                }
                "KMeans" | "GMM" | "KNN" | "DBSCAN" | "t-SNE" => Category::NeighbourBased,
                _ => Category::TreeBased,
            };
            assert_eq!(w.category(), expected, "{}", w.name());
        }
    }

    #[test]
    fn mlpack_gaps_match_paper() {
        for w in registry() {
            let expected = !matches!(w.name(), "SVM-RBF" | "LDA" | "t-SNE");
            assert_eq!(w.in_mlpack(), expected, "{}", w.name());
        }
    }

    #[test]
    fn by_name_variants() {
        assert!(by_name("kmeans").is_some());
        assert!(by_name("KMeans").is_some());
        assert!(by_name("random forests").is_some());
        assert!(by_name("svm-rbf").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn profile_support_matches_library_gaps() {
        let sk = supported_names(LibraryProfile::Sklearn);
        assert_eq!(sk.len(), 14, "sklearn implements all of Table I");
        let ml = supported_names(LibraryProfile::Mlpack);
        assert_eq!(ml.len(), 11);
        for missing in ["SVM-RBF", "LDA", "t-SNE"] {
            assert!(!ml.contains(&missing), "{missing} must not be in the mlpack set");
            let w = by_name(missing).unwrap();
            assert!(!LibraryProfile::Mlpack.implements(w.as_ref()));
            assert!(LibraryProfile::Sklearn.implements(w.as_ref()));
        }
    }

    #[test]
    fn multicore_lists_match_tables() {
        assert_eq!(multicore_names(LibraryProfile::Sklearn).len(), 8);
        assert_eq!(multicore_names(LibraryProfile::Mlpack).len(), 6);
        for n in multicore_names(LibraryProfile::Mlpack) {
            assert!(by_name(n).unwrap().in_mlpack());
        }
    }
}
