//! Principal Components Analysis [Pea01] — matrix-based workload.
//!
//! Covariance-based PCA: one streaming SYRK pass builds the M×M
//! covariance, then in-cache power iteration with deflation extracts the
//! top components (the LAPACK `syev` stand-in; same trace shape — the
//! dataset pass dominates at M ≪ N). Quality metric: explained variance
//! ratio of the extracted components.

use super::linalg;
use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_blobs, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::Matrix;

/// PCA workload.
pub struct Pca {
    /// Number of components to extract.
    pub n_components: usize,
    /// Power-iteration sweeps per component.
    pub power_iters: usize,
}

impl Default for Pca {
    fn default() -> Self {
        Self { n_components: 4, power_iters: 50 }
    }
}

impl Workload for Pca {
    fn name(&self) -> &'static str {
        "PCA"
    }

    fn category(&self) -> Category {
        Category::MatrixBased
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        // blobs give a clear low-dimensional structure to recover
        make_blobs(rows, features, 5, 1.5, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let (n, m) = (ds.n_samples(), ds.n_features());
        let k = self.n_components.min(m);
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("pca.x", n, m);
        let r_cov = space.alloc_matrix("pca.cov", m, m);

        // mean-center pass (one stream over the data)
        let mut mean = vec![0.0; m];
        for i in 0..n {
            rec.load_row(r_x, i, m);
            rec.compute(ctx.profile.loop_overhead_uops(), m as u32);
            for j in 0..m {
                mean[j] += ds.x[(i, j)];
            }
        }
        mean.iter_mut().for_each(|v| *v /= n as f64);

        // centered covariance via streaming SYRK (each "training
        // iteration" re-runs the dataset pass, as repeated fits would)
        let mut cov = Matrix::zeros(m, m);
        for _ in 0..ctx.iterations.max(1) {
            let gram = linalg::syrk(&ds.x, r_x, rec);
            for a in 0..m {
                for b in 0..m {
                    cov[(a, b)] = gram[(a, b)] / n as f64 - mean[a] * mean[b];
                }
            }
        }

        // power iteration with deflation (in-cache; small trace)
        let mut deflated = cov.clone();
        let mut eigvals = Vec::with_capacity(k);
        let mut rng = crate::util::Pcg64::new(ctx.seed);
        for _c in 0..k {
            let mut v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            normalize(&mut v);
            let mut lambda = 0.0;
            for _ in 0..self.power_iters {
                rec.load(r_cov.at(0), (m * m * 8) as u32);
                rec.compute(2, (2 * m * m) as u32);
                let mut next = vec![0.0; m];
                for a in 0..m {
                    for b in 0..m {
                        next[a] += deflated[(a, b)] * v[b];
                    }
                }
                lambda = norm(&next);
                if lambda == 0.0 {
                    break;
                }
                next.iter_mut().for_each(|x| *x /= lambda);
                v = next;
            }
            // deflate: A -= λ v vᵀ
            for a in 0..m {
                for b in 0..m {
                    deflated[(a, b)] -= lambda * v[a] * v[b];
                }
            }
            eigvals.push(lambda);
        }

        let total_var: f64 = (0..m).map(|d| cov[(d, d)]).sum();
        let explained: f64 = eigvals.iter().sum();
        let ratio = if total_var > 0.0 { explained / total_var } else { 0.0 };
        RunResult {
            quality: ratio,
            detail: format!("explained variance ratio {ratio:.4} over {k} components"),
        }
    }
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 0.0 {
        v.iter_mut().for_each(|x| *x /= n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn pca_explains_blob_variance() {
        let w = Pca::default();
        let ds = w.make_dataset(2000, 10, 11);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext { iterations: 1, ..Default::default() }, &mut rec);
        // 5 well-separated blobs live in a ≤4-dim affine subspace: top-4
        // components capture most of the variance
        assert!(res.quality > 0.8, "explained {}", res.quality);
        assert!(res.quality <= 1.0 + 1e-9);
    }

    #[test]
    fn more_components_explain_more() {
        let ds = Pca::default().make_dataset(1000, 8, 12);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let ctx = RunContext { iterations: 1, ..Default::default() };
        let q2 = Pca { n_components: 2, power_iters: 50 }.run(&ds, &ctx, &mut rec).quality;
        let q6 = Pca { n_components: 6, power_iters: 50 }.run(&ds, &ctx, &mut rec).quality;
        assert!(q6 >= q2 - 1e-9, "{q2} vs {q6}");
    }

    #[test]
    fn eigvals_nonnegative_and_sorted_by_construction() {
        // power iteration with deflation returns dominant-first values
        let w = Pca { n_components: 3, power_iters: 100 };
        let ds = w.make_dataset(500, 6, 13);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext { iterations: 1, ..Default::default() }, &mut rec);
        assert!(res.quality > 0.0);
    }
}
