//! Random Forests [Bre01] — tree-based workload.
//!
//! Bagged CART ensemble with per-node feature subsampling, as in both
//! scikit-learn's `RandomForestClassifier` and mlpack's
//! `RandomForest`. Each tree trains on a bootstrap **index array** —
//! random row indices into the dataset, so even the root-node scans are
//! irregular `X[idx[i]]` gathers (the forest's Table III DRAM bound of
//! 33.4% despite tree-local locality). Quality: train accuracy by
//! majority vote.

use super::dtree::{fit_cart, CartParams, CartRegions, CartTree};
use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_classification, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::Pcg64;

/// Random Forest workload.
pub struct RandomForest {
    pub n_trees: usize,
    pub max_depth: usize,
}

impl Default for RandomForest {
    fn default() -> Self {
        Self { n_trees: 10, max_depth: 8 }
    }
}

impl Workload for RandomForest {
    fn name(&self) -> &'static str {
        "Random Forests"
    }

    fn category(&self) -> Category {
        Category::TreeBased
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_classification(rows, features, (features * 3 / 4).max(2), 4, 0.08, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let n = ds.n_samples();
        let m = ds.n_features();
        let n_classes = ds.n_classes.max(2);
        let mut space = AddressSpace::new();
        let regions = CartRegions::alloc(&mut space, n, m, "rforest");
        let mut rng = Pcg64::new(ctx.seed);
        let params = CartParams {
            max_depth: self.max_depth,
            min_samples_leaf: 10,
            max_features: Some((m as f64).sqrt().ceil() as usize),
            n_thresholds: 8,
        };

        let mut trees: Vec<CartTree> = Vec::with_capacity(self.n_trees);
        for _t in 0..self.n_trees {
            // bootstrap sample: n draws with replacement — the random
            // index array that defeats spatial locality
            let mut idx: Vec<u32> = (0..n).map(|_| rng.below(n as u64) as u32).collect();
            // trace the bootstrap draw itself (index array construction)
            for i in 0..n {
                rec.store(regions.r_idx.elem(i, 4), 4);
            }
            rec.compute(n as u32, 0);
            trees.push(fit_cart(
                &ds.x,
                &ds.y,
                n_classes,
                &mut idx,
                None,
                &params,
                &regions,
                rec,
                &mut rng,
                ctx.profile.loop_overhead_uops(),
            ));
        }

        // traced ensemble prediction over the training set
        let mut correct = 0usize;
        let mut votes = vec![0usize; n_classes];
        for i in 0..n {
            votes.iter_mut().for_each(|v| *v = 0);
            for t in &trees {
                votes[t.predict_traced(&ds.x, i, &regions, rec)] += 1;
            }
            let pred = votes
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(c, _)| c)
                .unwrap_or(0);
            if pred == ds.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        let total_nodes: usize = trees.iter().map(|t| t.n_nodes()).sum();
        RunResult {
            quality: acc,
            detail: format!(
                "train accuracy {acc:.4}, {} trees, {total_nodes} total nodes",
                trees.len()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn forest_fits_classification_data() {
        let w = RandomForest { n_trees: 8, max_depth: 8 };
        let ds = w.make_dataset(800, 10, 44);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext::default(), &mut rec);
        assert!(res.quality > 0.8, "accuracy {} ({})", res.quality, res.detail);
    }

    #[test]
    fn more_trees_do_not_hurt() {
        let ds = RandomForest::default().make_dataset(500, 8, 45);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let one = RandomForest { n_trees: 1, max_depth: 6 }
            .run(&ds, &RunContext::default(), &mut rec);
        let many = RandomForest { n_trees: 12, max_depth: 6 }
            .run(&ds, &RunContext::default(), &mut rec);
        assert!(many.quality >= one.quality - 0.05, "{} vs {}", one.quality, many.quality);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = RandomForest { n_trees: 4, max_depth: 5 };
        let ds = w.make_dataset(300, 6, 46);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let a = w.run(&ds, &RunContext::default(), &mut rec);
        let b = w.run(&ds, &RunContext::default(), &mut rec);
        assert_eq!(a.quality, b.quality);
    }
}
