//! Ridge regression [HK00] — matrix-based workload.
//!
//! Solves the L2-regularized least squares problem in closed form via the
//! normal equations, `(XᵀX + αI) w = Xᵀy`, exactly as scikit-learn's
//! `Ridge(solver="cholesky")` and mlpack's `LinearRegression` do. The
//! trace is dominated by the SYRK pass over the dataset: long streaming
//! row loads and dense FP — the paper's "regular memory accesses, high
//! memory bandwidth utilization" matrix profile.

use super::linalg;
use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_regression, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::Matrix;

/// Ridge regression workload. Quality metric: training R².
pub struct Ridge {
    /// L2 penalty.
    pub alpha: f64,
}

impl Default for Ridge {
    fn default() -> Self {
        Self { alpha: 1.0 }
    }
}

/// Shared closed-form fit used by Ridge (and PCA's covariance step).
pub(crate) fn fit_normal_equations(
    x: &Matrix,
    y: &[f64],
    alpha: f64,
    space: &mut AddressSpace,
    rec: &mut Recorder,
    profile_overhead: u32,
) -> Vec<f64> {
    let m = x.cols();
    let r_x = space.alloc_matrix("ridge.x", x.rows(), m);
    let r_y = space.alloc_f64("ridge.y", y.len());
    let r_a = space.alloc_matrix("ridge.gram", m, m);
    // per-row interpreter/loop overhead of the library profile
    rec.compute(profile_overhead * x.rows() as u32 / 8, 0);
    let mut gram = linalg::syrk(x, r_x, rec);
    for d in 0..m {
        gram[(d, d)] += alpha;
    }
    let xty = linalg::xt_v(x, r_x, r_y, y, rec);
    linalg::chol_solve(&gram, &xty, r_a, rec)
}

/// Training R² of a linear model.
pub(crate) fn r_squared(x: &Matrix, y: &[f64], w: &[f64]) -> f64 {
    let n = x.rows();
    let mean_y: f64 = y.iter().sum::<f64>() / n as f64;
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for i in 0..n {
        let pred: f64 = x.row(i).iter().zip(w).map(|(a, b)| a * b).sum();
        ss_res += (y[i] - pred) * (y[i] - pred);
        ss_tot += (y[i] - mean_y) * (y[i] - mean_y);
    }
    if ss_tot == 0.0 {
        0.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

impl Workload for Ridge {
    fn name(&self) -> &'static str {
        "Ridge"
    }

    fn category(&self) -> Category {
        Category::MatrixBased
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_regression(rows, features, features * 3 / 4, 10.0, seed).0
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let mut space = AddressSpace::new();
        let mut w = Vec::new();
        // the paper runs up to 5 training iterations of each workload;
        // for a closed-form solver an "iteration" is a full refit pass
        for _ in 0..ctx.iterations.max(1) {
            w = fit_normal_equations(
                &ds.x,
                &ds.y,
                self.alpha,
                &mut space,
                rec,
                ctx.profile.loop_overhead_uops(),
            );
        }
        let r2 = r_squared(&ds.x, &ds.y, &w);
        RunResult { quality: r2, detail: format!("R²={r2:.4}, {} coefs", w.len()) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn ridge_fits_linear_data() {
        let w = Ridge::default();
        let ds = w.make_dataset(2000, 8, 5);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext::default(), &mut rec);
        assert!(res.quality > 0.95, "R² {}", res.quality);
    }

    #[test]
    fn heavier_regularization_shrinks_fit() {
        let ds = Ridge::default().make_dataset(500, 5, 6);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let loose = Ridge { alpha: 0.01 }.run(&ds, &RunContext::default(), &mut rec);
        let tight = Ridge { alpha: 1e5 }.run(&ds, &RunContext::default(), &mut rec);
        assert!(loose.quality > tight.quality);
    }

    #[test]
    fn trace_is_mostly_fp_and_streaming() {
        let w = Ridge::default();
        let ds = w.make_dataset(500, 8, 7);
        let mut mix = crate::trace::InstructionMix::default();
        {
            let mut rec = Recorder::new(&mut mix, 0);
            w.run(&ds, &RunContext { iterations: 1, ..Default::default() }, &mut rec);
        }
        assert!(mix.branch_fraction() < 0.15, "matrix workloads branch little");
        assert!(mix.fp_ops > mix.int_ops, "FP-dominated");
    }
}
