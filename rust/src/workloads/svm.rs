//! Support Vector Machines [Hea+98] — matrix-based workloads.
//!
//! Two variants, as in the paper:
//!
//! - [`LinearSvm`] — dual coordinate descent on the linear-kernel hinge
//!   SVM (liblinear's algorithm, sklearn's `LinearSVC`): per-sample row
//!   loads in shuffled order plus dense dot products.
//! - [`SvmRbf`] — kernel SVM (sklearn's `SVC(kernel="rbf")`, not in
//!   mlpack): single-violator SMO-style dual ascent where each update
//!   recomputes a full kernel row K(x_i, ·) with one streaming pass over
//!   the dataset — the most bandwidth-hungry workload in the suite.

use super::{linalg, Category, RunContext, RunResult, Workload};
use crate::data::{make_classification, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::Pcg64;

const SITE_VIOLATOR: u32 = 1;
const SITE_CLIP: u32 = 2;

/// Linear-kernel SVM via dual coordinate descent. Quality: train accuracy.
pub struct LinearSvm {
    /// Box constraint C.
    pub c: f64,
}

impl Default for LinearSvm {
    fn default() -> Self {
        Self { c: 1.0 }
    }
}

/// ±1 labels from a 2-class dataset.
fn signed_labels(ds: &Dataset) -> Vec<f64> {
    ds.y.iter().map(|&l| if l > 0.5 { 1.0 } else { -1.0 }).collect()
}

fn train_accuracy(ds: &Dataset, w: &[f64], b: f64) -> f64 {
    let y = signed_labels(ds);
    let mut correct = 0usize;
    for i in 0..ds.n_samples() {
        let s: f64 = ds.x.row(i).iter().zip(w).map(|(a, c)| a * c).sum::<f64>() + b;
        if (s >= 0.0) == (y[i] > 0.0) {
            correct += 1;
        }
    }
    correct as f64 / ds.n_samples() as f64
}

impl Workload for LinearSvm {
    fn name(&self) -> &'static str {
        "Linear SVM"
    }

    fn category(&self) -> Category {
        Category::MatrixBased
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_classification(rows, features, (features * 3 / 4).max(1), 2, 0.01, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let (n, m) = (ds.n_samples(), ds.n_features());
        let y = signed_labels(ds);
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("svm.x", n, m);
        let r_alpha = space.alloc_f64("svm.alpha", n);
        let mut rng = Pcg64::new(ctx.seed);
        let mut alpha = vec![0.0; n];
        let mut w = vec![0.0; m];
        let overhead = ctx.profile.loop_overhead_uops();
        let q_diag: Vec<f64> = (0..n)
            .map(|i| ds.x.row(i).iter().map(|v| v * v).sum::<f64>())
            .collect();

        let mut order: Vec<usize> = (0..n).collect();
        for _epoch in 0..ctx.iterations.max(1) {
            rng.shuffle(&mut order); // liblinear shuffles every epoch
            for &i in &order {
                rec.load_row(r_x, i, m);
                rec.load_f64(r_alpha, i);
                let _ = overhead;
                rec.profile_tick();
                rec.compute(1, (2 * m) as u32);
                rec.loop_branch(3, (m / 4).max(1) as u32);
                let xi = ds.x.row(i);
                let g = y[i] * xi.iter().zip(&w).map(|(a, b)| a * b).sum::<f64>() - 1.0;
                let pg = if alpha[i] == 0.0 {
                    g.min(0.0)
                } else if alpha[i] >= self.c {
                    g.max(0.0)
                } else {
                    g
                };
                if rec.fcmp_branch(SITE_VIOLATOR, pg.abs() > 1e-12) {
                    let qii = q_diag[i].max(1e-12);
                    let old = alpha[i];
                    alpha[i] = (old - g / qii).clamp(0.0, self.c);
                    rec.fcmp_branch(SITE_CLIP, alpha[i] == 0.0 || alpha[i] == self.c);
                    let d = (alpha[i] - old) * y[i];
                    if d != 0.0 {
                        rec.store_f64(r_alpha, i);
                        rec.compute(0, (2 * m) as u32);
                        for j in 0..m {
                            w[j] += d * xi[j];
                        }
                    }
                }
            }
        }
        let acc = train_accuracy(ds, &w, 0.0);
        let n_sv = alpha.iter().filter(|a| **a > 1e-12).count();
        RunResult { quality: acc, detail: format!("accuracy {acc:.4}, {n_sv} SVs") }
    }
}

/// RBF-kernel SVM via single-violator dual ascent. Quality: train accuracy
/// on a held-in probe subset.
pub struct SvmRbf {
    pub c: f64,
    /// RBF bandwidth γ.
    pub gamma: f64,
    /// Dual updates per "training iteration".
    pub updates_per_iter: usize,
}

impl Default for SvmRbf {
    fn default() -> Self {
        Self { c: 1.0, gamma: 0.05, updates_per_iter: 24 }
    }
}

impl Workload for SvmRbf {
    fn name(&self) -> &'static str {
        "SVM-RBF"
    }

    fn category(&self) -> Category {
        Category::MatrixBased
    }

    fn in_mlpack(&self) -> bool {
        false // mlpack implements no RBF-kernel SVM (paper Section II)
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_classification(rows, features, (features * 3 / 4).max(1), 2, 0.02, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let (n, m) = (ds.n_samples(), ds.n_features());
        let y = signed_labels(ds);
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("svmrbf.x", n, m);
        let r_f = space.alloc_f64("svmrbf.f", n);
        let mut alpha = vec![0.0; n];
        // f_i = decision value at x_i (dual gradient bookkeeping, as SMO)
        let mut f = vec![0.0; n];
        let mut krow = vec![0.0; n];
        let overhead = ctx.profile.loop_overhead_uops();

        for _it in 0..ctx.iterations.max(1) {
            for _u in 0..self.updates_per_iter {
                // pick the worst KKT violator: one pass over f (streaming)
                rec.load(r_f.f64(0), (n * 8) as u32);
                let _ = overhead;
                rec.profile_tick();
                rec.compute(1, (2 * n) as u32);
                let mut best = 0usize;
                let mut best_v: f64 = -1.0;
                for i in 0..n {
                    let viol = if y[i] > 0.0 { 1.0 - f[i] } else { 1.0 + f[i] };
                    let capped = alpha[i] < self.c;
                    let v = if capped { viol } else { 0.0 };
                    if v > best_v {
                        best_v = v;
                        best = i;
                    }
                }
                if best_v <= 1e-9 {
                    break;
                }
                // kernel row K(x_best, ·): streaming sqdist + exp pass
                linalg::sqdist_row(&ds.x, r_x, ds.x.row(best), &mut krow, rec);
                rec.compute(0, (4 * n) as u32); // exp()
                for k in krow.iter_mut() {
                    *k = (-self.gamma * *k).exp();
                }
                // dual step on alpha_best
                let step = (best_v / 1.0).clamp(0.0, self.c - alpha[best]);
                alpha[best] += step;
                // f update: one more streaming pass
                rec.load(r_f.f64(0), (n * 8) as u32);
                rec.store(r_f.f64(0), (n * 8) as u32);
                rec.compute(0, (2 * n) as u32);
                for i in 0..n {
                    f[i] += step * y[best] * krow[i];
                }
            }
        }
        // probe accuracy via the maintained decision values
        let mut correct = 0usize;
        for i in 0..n {
            if (f[i] >= 0.0) == (y[i] > 0.0) {
                correct += 1;
            }
        }
        let acc = correct as f64 / n as f64;
        let n_sv = alpha.iter().filter(|a| **a > 1e-12).count();
        RunResult { quality: acc, detail: format!("accuracy {acc:.4}, {n_sv} SVs") }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{InstructionMix, NullSink};

    #[test]
    fn linear_svm_separates() {
        let w = LinearSvm::default();
        let ds = w.make_dataset(1500, 10, 14);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext { iterations: 10, ..Default::default() }, &mut rec);
        assert!(res.quality > 0.85, "accuracy {} ({})", res.quality, res.detail);
    }

    #[test]
    fn rbf_svm_learns() {
        let w = SvmRbf { updates_per_iter: 60, ..Default::default() };
        let ds = w.make_dataset(600, 8, 15);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext { iterations: 5, ..Default::default() }, &mut rec);
        assert!(res.quality > 0.75, "accuracy {} ({})", res.quality, res.detail);
    }

    #[test]
    fn rbf_is_bandwidth_heavy() {
        let w = SvmRbf::default();
        let ds = w.make_dataset(500, 8, 16);
        let mut mix = InstructionMix::default();
        {
            let mut rec = Recorder::new(&mut mix, 0);
            w.run(&ds, &RunContext { iterations: 2, ..Default::default() }, &mut rec);
        }
        // every update streams the whole dataset: bytes ≫ dataset size
        assert!(mix.bytes_loaded > 4 * ds.bytes());
        assert!(mix.branch_fraction() < 0.15);
    }

    #[test]
    fn labels_are_signed() {
        let ds = LinearSvm::default().make_dataset(100, 5, 17);
        let y = signed_labels(&ds);
        assert!(y.iter().all(|&v| v == 1.0 || v == -1.0));
        assert!(y.iter().any(|&v| v == 1.0) && y.iter().any(|&v| v == -1.0));
    }
}
