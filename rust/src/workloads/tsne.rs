//! t-SNE embedding [MH08] — neighbour-based workload.
//!
//! Nearest-neighbour t-SNE in the style of scikit-learn's Barnes–Hut
//! implementation (mlpack has none): a K-D-tree kNN-graph construction
//! phase, then gradient iterations whose attractive forces gather
//! embedding rows through the neighbour index lists — indirect `Y[nn[j]]`
//! loads over a shuffled graph, the paper's worst row-buffer locality
//! case (Table VII: hit ratio 0.18). Repulsive forces use a sampled
//! negative set (the Barnes–Hut tree approximation's access pattern is
//! likewise irregular). Quality metric: ratio of mean embedded
//! neighbour distance to mean embedded random-pair distance (smaller =
//! structure preserved; decreases over iterations).

use super::kdtree::TraceTree;
use super::knn::tree_kind;
use super::{Category, RunContext, RunResult, Workload};
use crate::data::{make_blobs, Dataset};
use crate::trace::{AddressSpace, Recorder};
use crate::util::Pcg64;

/// t-SNE workload.
pub struct Tsne {
    /// Neighbours per point in the attraction graph.
    pub k: usize,
    /// Output dimensionality.
    pub dim: usize,
    /// Gradient steps per "training iteration".
    pub steps_per_iter: usize,
    /// Negative samples per point per step.
    pub negatives: usize,
    pub learning_rate: f64,
}

impl Default for Tsne {
    fn default() -> Self {
        Self { k: 8, dim: 2, steps_per_iter: 10, negatives: 4, learning_rate: 0.25 }
    }
}

impl Workload for Tsne {
    fn name(&self) -> &'static str {
        "t-SNE"
    }

    fn category(&self) -> Category {
        Category::NeighbourBased
    }

    fn in_mlpack(&self) -> bool {
        false
    }

    fn supports_visit_order(&self) -> bool {
        true
    }

    fn make_dataset(&self, rows: usize, features: usize, seed: u64) -> Dataset {
        make_blobs(rows, features, 5, 1.0, seed)
    }

    fn run(&self, ds: &Dataset, ctx: &RunContext, rec: &mut Recorder) -> RunResult {
        let n = ds.n_samples();
        let m = ds.n_features();
        let d = self.dim;
        let mut space = AddressSpace::new();
        let r_x = space.alloc_matrix("tsne.x", n, m);
        let r_y = space.alloc_matrix("tsne.y", n, d);
        let r_nn = space.alloc("tsne.nn", (n * self.k) as u64 * 4);
        let overhead = ctx.profile.loop_overhead_uops();

        // Phase 1: kNN graph via the spatial tree.
        let tree =
            TraceTree::build(&ds.x, r_x, &mut space, tree_kind(ctx.profile), 30, rec);
        let mut nn = vec![0u32; n * self.k];
        for i in 0..n {
            rec.load_row(r_x, i, m);
            let found = tree.knn(&ds.x, ds.x.row(i), self.k + 1, rec, 8);
            for (j, &(_, r)) in found.iter().skip(1).take(self.k).enumerate() {
                nn[i * self.k + j] = r;
            }
            rec.store(r_nn.elem(i * self.k, 4), (self.k * 4) as u32);
        }

        // Phase 2: gradient iterations over the embedding.
        let mut rng = Pcg64::new(ctx.seed);
        let mut y: Vec<f64> = (0..n * d).map(|_| rng.normal() * 1e-2).collect();
        let default_order: Vec<usize> = (0..n).collect();
        let order = ctx.visit_order.as_deref().unwrap_or(&default_order);
        assert_eq!(order.len(), n, "visit order must cover all samples");

        for _iter in 0..ctx.iterations.max(1) {
            for _step in 0..self.steps_per_iter {
                for &i in order {
                    rec.load_row(r_y, i, d);
                    rec.load(r_nn.elem(i * self.k, 4), (self.k * 4) as u32);
                    let _ = overhead;
                    rec.profile_tick();
                    rec.compute(2, (self.k * (3 * d + 4)) as u32);
                    let mut grad = vec![0.0; d];
                    // attractive forces toward graph neighbours: the
                    // indirect Y[nn[j]] gather
                    for jj in 0..self.k {
                        if jj + 2 < self.k {
                            let ahead = nn[i * self.k + jj + 2] as usize;
                            rec.prefetch(r_y.f64(ahead * d), (d * 8) as u32);
                        }
                        let j = nn[i * self.k + jj] as usize;
                        rec.load_indirect_row(r_nn, i * self.k + jj, r_y, j, d);
                        rec.loop_branch(1, d as u32);
                        let mut sq = 0.0;
                        for t in 0..d {
                            let diff = y[i * d + t] - y[j * d + t];
                            sq += diff * diff;
                        }
                        let w = 1.0 / (1.0 + sq);
                        for t in 0..d {
                            grad[t] += w * (y[j * d + t] - y[i * d + t]);
                        }
                    }
                    // sampled repulsive forces
                    for _neg in 0..self.negatives {
                        let j = rng.index(n);
                        rec.load_row(r_y, j, d);
                        rec.compute(1, (3 * d + 4) as u32);
                        let mut sq = 0.0;
                        for t in 0..d {
                            let diff = y[i * d + t] - y[j * d + t];
                            sq += diff * diff;
                        }
                        let w = 1.0 / (1.0 + sq);
                        for t in 0..d {
                            grad[t] -= 0.5 * w * w * (y[j * d + t] - y[i * d + t]);
                        }
                    }
                    for t in 0..d {
                        y[i * d + t] += self.learning_rate * grad[t];
                    }
                    rec.store_row(r_y, i, d);
                }
            }
        }

        // Quality: embedded neighbour distance vs random-pair distance.
        let mut nn_dist = 0.0;
        let mut rnd_dist = 0.0;
        let probes = n.min(2000);
        for i in 0..probes {
            let j = nn[i * self.k] as usize;
            let r = rng.index(n);
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            for t in 0..d {
                let a = y[i * d + t] - y[j * d + t];
                let b = y[i * d + t] - y[r * d + t];
                s1 += a * a;
                s2 += b * b;
            }
            nn_dist += s1.sqrt();
            rnd_dist += s2.sqrt();
        }
        let ratio = if rnd_dist > 0.0 { nn_dist / rnd_dist } else { 1.0 };
        RunResult {
            quality: -ratio, // larger = better, like the other workloads
            detail: format!("nn/random embedded distance ratio {ratio:.4}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn embedding_pulls_neighbours_closer() {
        let w = Tsne::default();
        let ds = w.make_dataset(400, 6, 38);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let res = w.run(&ds, &RunContext { iterations: 5, ..Default::default() }, &mut rec);
        let ratio = -res.quality;
        assert!(ratio < 0.8, "neighbours not pulled together: ratio {ratio}");
    }

    #[test]
    fn more_iterations_improve_or_hold_structure() {
        let w = Tsne::default();
        let ds = w.make_dataset(200, 5, 39);
        let mut sink = NullSink;
        let mut rec = Recorder::new(&mut sink, 0);
        let q1 = w.run(&ds, &RunContext { iterations: 1, ..Default::default() }, &mut rec).quality;
        let q6 = w.run(&ds, &RunContext { iterations: 6, ..Default::default() }, &mut rec).quality;
        assert!(q6 >= q1 - 0.05, "{q1} -> {q6}");
    }

    #[test]
    fn trace_contains_indirect_gathers() {
        let w = Tsne { steps_per_iter: 2, ..Default::default() };
        let ds = w.make_dataset(150, 5, 40);
        let mut sink = crate::trace::VecSink::default();
        {
            let mut rec = Recorder::new(&mut sink, 0);
            w.run(&ds, &RunContext { iterations: 1, ..Default::default() }, &mut rec);
        }
        let small_idx_loads = sink
            .events
            .iter()
            .filter(|e| matches!(e, crate::trace::Event::Load { size: 4, .. }))
            .count();
        assert!(small_idx_loads > 500, "index loads {small_idx_loads}");
    }
}
