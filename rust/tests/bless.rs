//! Baseline blessing flow (`mlperf report --bless`) at the library
//! level: a blessed results file must gate a bit-identical re-run
//! cleanly, catch any perturbation, flag vanished cells, and round-trip
//! sampled-grid provenance. Also pins the semantics of the committed
//! placeholder baseline: an empty cell list parses and gates vacuously.

use mlperf::coordinator::{run_jobs_replayed, ExperimentConfig};
use mlperf::ledger::{diff, GridResults};
use mlperf::sim::SampleConfig;

mod common;

/// The exact flow `report --bless` runs: execute the grid, serialize,
/// commit. Gating is then `diff(current, blessed, tol)`.
fn bless(cfg: &ExperimentConfig, name: &str) -> (GridResults, std::path::PathBuf) {
    let jobs = common::scenario_jobs();
    let report = run_jobs_replayed(cfg, &jobs, 2);
    let current = GridResults::from_outputs(cfg, &report.outputs);
    let path = common::tmpfile("bless", name);
    current.save(&path).unwrap();
    (current, path)
}

#[test]
fn gating_against_a_blessed_baseline_passes_and_perturbed_copies_fail() {
    let cfg = common::tiny();
    let (_, path) = bless(&cfg, "blessed.json");
    let blessed = GridResults::load(&path).unwrap();
    assert_eq!(blessed.cells.len(), common::scenario_jobs().len());

    // an independent re-run of the same grid must gate cleanly at zero
    // tolerance: the simulation is deterministic and JSON round-trips
    // f64 shortest-form exactly
    let rerun = run_jobs_replayed(&cfg, &common::scenario_jobs(), 4);
    let current = GridResults::from_outputs(&cfg, &rerun.outputs);
    let report = diff(&current, &blessed, 0.0);
    assert!(
        report.pass(),
        "re-run drifted from its own blessed baseline: {:?}",
        report.rows.iter().find(|r| !r.within)
    );
    assert!(report.missing.is_empty());

    // any numeric perturbation of the blessed file must fail the gate
    let mut perturbed = blessed.clone();
    perturbed.cells[0].metrics[0].1 *= 1.05;
    let report = diff(&current, &perturbed, 0.01);
    assert!(!report.pass(), "5% drift slipped through a 1% gate");
    assert!(report.drifted() >= 1);

    // a cell vanishing from the current run is a regression, not a skip
    let mut shrunk = current.clone();
    shrunk.cells.pop();
    let report = diff(&shrunk, &blessed, 0.01);
    assert!(!report.pass(), "a vanished cell must fail the gate");
    assert_eq!(report.missing.len(), 1);
}

#[test]
fn blessing_a_sampled_grid_round_trips_sampling_provenance() {
    let sample = SampleConfig { detail: 2, period: 16 };
    let cfg = ExperimentConfig { sample: Some(sample), ..common::tiny() };
    let (current, path) = bless(&cfg, "blessed_sampled.json");
    let blessed = GridResults::load(&path).unwrap();

    assert_eq!(blessed.sample, Some(sample), "sampling params must survive blessing");
    // broadcast-replayed cells carry their interval; cells that ran
    // direct (the multicore column, single-cell capture groups) must
    // not pretend to be estimates
    let kmeans_baseline = blessed
        .cells
        .iter()
        .find(|c| c.workload == "KMeans" && c.scenario == "baseline")
        .expect("grid must contain KMeans/baseline");
    assert!(kmeans_baseline.cpi_ci95.is_some(), "sampled cell lost its CI");
    let multicore = blessed
        .cells
        .iter()
        .find(|c| c.scenario == "2-core")
        .expect("grid must contain the multicore cell");
    assert!(
        multicore.cpi_ci95.is_none(),
        "a direct-executed cell claims a confidence interval"
    );

    // the blessed file gates its own run exactly
    assert!(diff(&current, &blessed, 0.0).pass());

    // and a sampled baseline is still a *different machine contract*
    // than a full one: same grid run unsampled shares no fingerprints
    let full_cfg = common::tiny();
    let rerun = run_jobs_replayed(&full_cfg, &common::scenario_jobs(), 2);
    let full = GridResults::from_outputs(&full_cfg, &rerun.outputs);
    for (a, b) in full.cells.iter().zip(&blessed.cells) {
        assert_ne!(
            a.fingerprint, b.fingerprint,
            "{}/{}: sampled and full cells must never share a fingerprint",
            a.workload, a.scenario
        );
    }
}

#[test]
fn committed_placeholder_baseline_parses_and_gates_vacuously() {
    // the repo ships an empty baseline until someone runs
    // `report --bless`; it must parse and pass every run (no cells to
    // compare) while counting everything as untracked
    let path = std::path::Path::new(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../BENCH_grid_baseline.json"
    ));
    let baseline = GridResults::load(path).expect("committed baseline must always parse");

    let cfg = common::tiny();
    let rerun = run_jobs_replayed(&cfg, &common::scenario_jobs(), 2);
    let current = GridResults::from_outputs(&cfg, &rerun.outputs);
    let report = diff(&current, &baseline, 0.01);
    if baseline.cells.is_empty() {
        assert!(report.pass(), "an empty baseline must gate vacuously");
        assert_eq!(report.rows.len(), 0);
        assert_eq!(report.untracked, current.cells.len());
    } else {
        // once a real baseline is blessed (different scale/profile than
        // the tiny test grid), it must at minimum keep parsing and
        // carry fingerprints for every cell
        assert!(baseline.cells.iter().all(|c| c.fingerprint.is_some()));
    }
}
