//! Broadcast-replay parity gates: feeding N simulators from one decoded
//! block stream (decode once, simulate many) must be bit-identical to
//! replaying the stream once per cell — for in-memory captures
//! (`replay_characterize_many`, the grid driver's broadcast batches) and
//! for file traces (`replay_file_many`, synchronous and pipelined
//! ingest) — and must actually decode **once**: the consume counters
//! equal the trace's block count no matter how wide the fan-out.

use mlperf::coordinator::{
    record_characterize, replay_characterize, replay_characterize_many, replay_file,
    replay_file_many, run_jobs, run_jobs_replayed, ExperimentConfig, Job, Scenario,
};
use mlperf::trace::{BlockSink, Broadcast, EventBlock, NullSink};

mod common;

fn tiny() -> ExperimentConfig {
    common::tiny()
}

fn tmpfile(name: &str) -> std::path::PathBuf {
    common::tmpfile("broadcast", name)
}

#[test]
fn broadcast_grid_is_bit_identical_to_per_cell_execution() {
    let cfg = tiny();
    // three workloads × {prefetch on/off} cells plus a non-replayable
    // multicore cell, the shape ISSUE's parity gate asks for
    let mut jobs: Vec<Job> = Vec::new();
    for w in ["KMeans", "KNN", "DBSCAN"] {
        for s in [
            Scenario::Baseline,
            Scenario::NoHwPrefetch,
            Scenario::PerfectLlc,
            Scenario::SwPrefetch,
        ] {
            jobs.push(Job::new(w, s));
        }
    }
    jobs.push(Job::new("GMM", Scenario::Multicore(2)));

    let direct = run_jobs(&cfg, &jobs, 2);
    // threads = 1 forces maximal broadcast batches; threads = 8 forces
    // single-cell batches (pure fan-out) — both must match direct
    for threads in [1usize, 2, 8] {
        let replayed = run_jobs_replayed(&cfg, &jobs, threads);
        assert_eq!(replayed.outputs.len(), jobs.len());
        // per workload: one no-prefetch capture (3 cells) + the
        // single-cell SwPrefetch group running direct = 2 executions,
        // plus the multicore cell
        assert_eq!(replayed.workload_executions, 7, "threads={threads}");
        for (a, b) in direct.outputs.iter().zip(&replayed.outputs) {
            assert_eq!(a.job, b.job, "threads={threads}: output order");
            assert_eq!(
                a.metrics, b.metrics,
                "threads={threads}: broadcast diverged for {:?}",
                a.job
            );
            assert_eq!(a.quality, b.quality);
        }
    }
}

#[test]
fn replay_characterize_many_matches_singles() {
    let cfg = tiny();
    let rec = common::capture("KNN", &cfg, false);
    let scenarios = [
        Scenario::Baseline,
        Scenario::PerfectL2,
        Scenario::NoHwPrefetch,
        Scenario::DramIdealRows,
    ];
    let many = replay_characterize_many(&rec, &cfg, &scenarios);
    assert_eq!(many.len(), scenarios.len());
    for (s, m) in scenarios.iter().zip(&many) {
        let single = replay_characterize(&rec, &cfg, |c| s.apply_cpu(c));
        assert_eq!(*m, single, "{s}: broadcast fan-out != solo replay");
    }
}

#[test]
fn in_memory_broadcast_walks_the_stream_once() {
    let cfg = tiny();
    let rec = common::capture("Ridge", &cfg, false);

    struct Count(u64);
    impl BlockSink for Count {
        fn consume(&mut self, _b: &EventBlock) {
            self.0 += 1;
        }
        fn finalize(&mut self) {}
    }
    let mut n = Count(0);
    rec.trace.replay_into(&mut n);
    assert!(n.0 > 0, "trivial trace");

    let (mut a, mut b, mut c) = (NullSink, NullSink, NullSink);
    let mut bc = Broadcast::new(vec![&mut a, &mut b, &mut c]);
    rec.trace.replay_into(&mut bc);
    assert_eq!(bc.fan_out(), 3);
    assert_eq!(
        bc.blocks_broadcast(),
        n.0,
        "three sinks must cost one stream walk, not three"
    );
}

#[test]
fn file_broadcast_decodes_once_and_matches_singles() {
    let cfg = tiny();
    let w = common::workload("KMeans");
    let path = tmpfile("bc_kmeans.mlt");
    let (_, summary) = record_characterize(w.as_ref(), &cfg, false, &path).unwrap();
    let scenarios = [
        Scenario::Baseline,
        Scenario::PerfectL2,
        Scenario::PerfectLlc,
        Scenario::NoHwPrefetch,
    ];
    // ingest_threads = 1 exercises the synchronous source, 3 the
    // pipelined ingest — the ISSUE's disk path through PipelinedIngest
    for threads in [1usize, 3] {
        let c = ExperimentConfig { ingest_threads: threads, ..tiny() };
        let (meta, metrics, stats) = replay_file_many(&path, &c, &scenarios).unwrap();
        assert_eq!(meta.workload, "KMeans");
        assert_eq!(
            stats.blocks, summary.blocks,
            "ingest_threads={threads}: one decode regardless of fan-out width"
        );
        assert_eq!(stats.events, summary.events);
        assert_eq!(metrics.len(), scenarios.len());
        for (s, m) in scenarios.iter().zip(&metrics) {
            let (_, single, _) = replay_file(&path, &c, |cc| s.apply_cpu(cc)).unwrap();
            assert_eq!(*m, single, "ingest_threads={threads}/{s}: fan-out != solo");
        }
    }
}
