//! Chaos-hardening integration tests: deterministic fault injection
//! (`util::fault`) driven through every layer it hooks — trace reads and
//! writes, the pipelined decoder pool, the grid drivers, and the ledger
//! store. The contracts under test: an empty plan changes nothing,
//! transient faults below the retry budget are invisible, permanent
//! faults quarantine exactly their own cells while the rest of the grid
//! stays bit-identical, crashes leave recoverable files behind, and a
//! killed ledgered run resumes by re-executing only the missing cells.
//!
//! The fault plan is process-global, so every test that installs one (or
//! that measures a clean reference) serializes through [`chaos_lock`]
//! and disarms via the panic-safe [`Armed`] guard.

use std::process::Command;
use std::sync::{Mutex, MutexGuard};

use mlperf::coordinator::{record_characterize, replay_file, ExperimentConfig, Job, Scenario};
use mlperf::coordinator::{run_jobs_ledgered, run_jobs_replayed};
use mlperf::ledger::{GridResults, Ledger};
use mlperf::util::fault::{self, FaultPlan, Site};

mod common;

fn tiny() -> ExperimentConfig {
    common::tiny()
}

fn tmpfile(name: &str) -> std::path::PathBuf {
    common::tmpfile("chaos", name)
}

fn bin() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_mlperf"));
    // the spawned CLI must only see the chaos spec the test passes
    c.env_remove("MLPERF_CHAOS");
    c
}

/// Serialize tests that touch the process-global fault plan (or that
/// need a fault-free reference run). `unwrap_or_else` recovers a lock
/// poisoned by an earlier failing test so one failure does not cascade.
fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arms the given chaos spec for one scope and disarms on drop — even
/// when an assertion panics mid-test, the next test starts clean.
struct Armed;

impl Armed {
    fn new(spec: &str) -> Armed {
        let plan = FaultPlan::parse(spec).expect("chaos spec must parse");
        fault::install(Some(plan));
        Armed
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        fault::install(None);
    }
}

/// Two cells sharing one KMeans capture — the smallest replayable grid.
fn kmeans_pair() -> Vec<Job> {
    vec![
        Job::new("KMeans", Scenario::Baseline),
        Job::new("KMeans", Scenario::PerfectL2),
    ]
}

#[test]
fn chaos_specs_parse_and_roundtrip() {
    let plan = FaultPlan::parse("seed=7; read-transient@2; stall%0.25=10").unwrap();
    assert_eq!(plan.seed(), 7);
    assert_eq!(plan.rule_count(), 2);
    assert!(!plan.is_empty());
    let rendered = plan.to_string();
    let reparsed = FaultPlan::parse(&rendered).unwrap().to_string();
    assert_eq!(reparsed, rendered, "Display must round-trip through parse");

    assert!(FaultPlan::parse("").unwrap().is_empty());
    let seeded = FaultPlan::parse("seed=3").unwrap();
    assert!(seeded.is_empty(), "a seed alone schedules nothing");
    assert!(FaultPlan::parse("flux-capacitor@1").is_err());
    assert!(FaultPlan::parse("read-transient").is_err());
    assert!(FaultPlan::parse("read-transient@0").is_err());
    assert!(FaultPlan::parse("stall%1.5").is_err());
    assert!(FaultPlan::parse("seed=x").is_err());
}

#[test]
fn empty_plan_is_never_armed_and_changes_nothing() {
    let _lock = chaos_lock();
    let cfg = tiny();
    let jobs = common::scenario_jobs();
    fault::install(None);
    let clean = run_jobs_replayed(&cfg, &jobs, 1);

    // a rules-free plan (even a seeded one) must not arm the hooks
    fault::install(Some(FaultPlan::parse("seed=42").unwrap()));
    assert!(!fault::armed(), "empty plan must stay disarmed");
    let under = run_jobs_replayed(&cfg, &jobs, 1);
    fault::install(None);

    assert!(clean.failed.is_empty());
    assert!(under.failed.is_empty());
    assert_eq!(clean.outputs.len(), jobs.len());
    assert_eq!(under.outputs.len(), jobs.len());
    for (a, b) in clean.outputs.iter().zip(&under.outputs) {
        assert_eq!(a.job, b.job);
        common::assert_metrics_eq(&a.metrics, &b.metrics, "empty plan perturbed the grid");
        assert_eq!(a.quality, b.quality);
    }
}

#[test]
fn transient_read_faults_are_retried_to_identical_results() {
    let _lock = chaos_lock();
    let cfg = tiny();
    let w = common::workload("KMeans");
    let path = tmpfile("kmeans_transient.mlt");
    record_characterize(w.as_ref(), &cfg, false, &path).unwrap();
    let (_, clean, _) = replay_file(&path, &cfg, |_| {}).unwrap();

    let _armed = Armed::new("read-transient@2;read-short@1");
    let (_, faulted, _) = replay_file(&path, &cfg, |_| {}).unwrap();
    assert_eq!(fault::fires_at(Site::ReadTransient), 1);
    assert_eq!(fault::fires_at(Site::ReadShort), 1);
    common::assert_metrics_eq(&faulted, &clean, "retried replay diverged");
}

#[test]
fn frame_bitflip_surfaces_a_corrupt_trace_error() {
    let _lock = chaos_lock();
    let cfg = tiny();
    let w = common::workload("KNN");
    let path = tmpfile("knn_bitflip.mlt");
    record_characterize(w.as_ref(), &cfg, false, &path).unwrap();

    let _armed = Armed::new("frame-bitflip@1");
    let err = replay_file(&path, &cfg, |_| {}).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");
}

#[test]
fn decoder_panic_becomes_a_typed_error_not_a_crash() {
    let _lock = chaos_lock();
    let mut cfg = tiny();
    cfg.ingest_threads = 3; // force the pipelined ingest (decoder pool)
    let w = common::workload("KMeans");
    let path = tmpfile("kmeans_decode_panic.mlt");
    record_characterize(w.as_ref(), &cfg, false, &path).unwrap();

    let _armed = Armed::new("decode-panic@1");
    let err = replay_file(&path, &cfg, |_| {}).unwrap_err().to_string();
    assert!(err.contains("decoder thread panicked"), "{err}");
    assert!(err.contains("injected decoder panic"), "{err}");
}

#[test]
fn decoder_stall_does_not_perturb_results() {
    let _lock = chaos_lock();
    let mut cfg = tiny();
    cfg.ingest_threads = 3;
    let w = common::workload("KMeans");
    let path = tmpfile("kmeans_stall.mlt");
    record_characterize(w.as_ref(), &cfg, false, &path).unwrap();
    let (_, clean, _) = replay_file(&path, &cfg, |_| {}).unwrap();

    let _armed = Armed::new("stall@1=5");
    let (_, stalled, _) = replay_file(&path, &cfg, |_| {}).unwrap();
    assert_eq!(fault::fires_at(Site::Stall), 1);
    common::assert_metrics_eq(&stalled, &clean, "stalled replay diverged");
}

#[test]
fn torn_tail_write_fails_the_recording_and_reads_back_truncated() {
    let _lock = chaos_lock();
    let cfg = tiny();
    let w = common::workload("KNN");
    let path = tmpfile("knn_torn.mlt");
    {
        let _armed = Armed::new("torn-tail@1");
        let res = record_characterize(w.as_ref(), &cfg, false, &path);
        let err = res.map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("injected torn tail write"), "{err:?}");
    }
    // the half-written frame stays on disk; reading it back must be a
    // clean truncation diagnosis, not a panic or a silent short trace
    let err = replay_file(&path, &cfg, |_| {}).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");
}

#[test]
fn capture_panic_quarantines_its_group_and_spares_the_rest() {
    let _lock = chaos_lock();
    let cfg = tiny();
    // KMeans ×4 rides one capture; KNN and GMM run as direct cells
    let jobs = common::scenario_jobs();
    fault::install(None);
    let clean = run_jobs_replayed(&cfg, &jobs, 1);
    assert!(clean.failed.is_empty());

    let _armed = Armed::new("capture-panic@1");
    let report = run_jobs_replayed(&cfg, &jobs, 1);
    assert_eq!(report.failed.len(), 4, "whole KMeans group quarantined");
    for (k, f) in report.failed.iter().enumerate() {
        assert_eq!(f.index, k, "failures sorted by grid position");
        assert_eq!(f.job.workload, "KMeans");
        assert_eq!(f.kind, "panic");
        assert!(f.error.contains("capture failed"), "{}", f.error);
        assert!(f.error.contains("injected capture panic"), "{}", f.error);
        assert_eq!(f.retries, 0);
    }
    // degrade, don't die: the independent cells complete bit-identically
    assert_eq!(report.outputs.len(), 2);
    assert_eq!(report.workload_executions, 2, "only direct cells ran");
    for out in &report.outputs {
        let same = clean.outputs.iter().find(|o| o.job == out.job);
        let reference = same.expect("healthy cell missing from clean run");
        common::assert_metrics_eq(&out.metrics, &reference.metrics, "healthy cell drifted");
        assert_eq!(out.quality, reference.quality);
    }
}

#[test]
fn cell_panic_quarantines_batch_and_direct_cells() {
    let _lock = chaos_lock();
    let cfg = tiny();
    let jobs = common::scenario_jobs();
    fault::install(None);
    let clean = run_jobs_replayed(&cfg, &jobs, 1);

    // occurrence 1 with one worker is the KMeans broadcast batch: the
    // capture survives but its four replay cells are quarantined
    {
        let _armed = Armed::new("cell-panic@1");
        let report = run_jobs_replayed(&cfg, &jobs, 1);
        assert_eq!(report.failed.len(), 4);
        for f in &report.failed {
            assert_eq!(f.job.workload, "KMeans");
            assert!(f.error.contains("replay failed"), "{}", f.error);
        }
        assert_eq!(report.outputs.len(), 2);
        assert_eq!(report.workload_executions, 3, "capture + 2 direct");
    }

    // occurrence 2 is the first direct cell (KNN sw-prefetch): exactly
    // one cell fails and every other cell matches the clean run
    let _armed = Armed::new("cell-panic@2");
    let report = run_jobs_replayed(&cfg, &jobs, 1);
    assert_eq!(report.failed.len(), 1);
    let f = &report.failed[0];
    assert_eq!(f.index, 4);
    assert_eq!(f.job.workload, "KNN");
    assert_eq!(f.job.scenario, Scenario::SwPrefetch);
    assert!(f.error.contains("injected cell panic"), "{}", f.error);
    assert_eq!(report.outputs.len(), jobs.len() - 1);
    for out in &report.outputs {
        let same = clean.outputs.iter().find(|o| o.job == out.job);
        let reference = same.expect("healthy cell missing from clean run");
        common::assert_metrics_eq(&out.metrics, &reference.metrics, "healthy cell drifted");
    }
}

#[test]
fn strict_mode_fails_fast_on_the_first_failure() {
    let _lock = chaos_lock();
    let mut cfg = tiny();
    cfg.strict = true;
    let jobs = common::scenario_jobs();

    let _armed = Armed::new("capture-panic@1");
    let report = run_jobs_replayed(&cfg, &jobs, 1);
    assert_eq!(report.failed.len(), 4, "failing group still reported");
    assert!(report.outputs.is_empty(), "--strict must abort remaining cells");
}

#[test]
fn transient_ledger_io_is_retried_below_budget() {
    let _lock = chaos_lock();
    let cfg = tiny();
    let jobs = kmeans_pair();
    let path = tmpfile("ledger_transient.mllg");
    {
        let _armed = Armed::new("ledger-io@1");
        let mut ledger = Ledger::open(&path).unwrap();
        let report = run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
        assert!(report.failed.is_empty(), "transient I/O must not quarantine");
        assert_eq!(report.outputs.len(), 2);
        assert_eq!(fault::fires_at(Site::LedgerIo), 1, "fault never injected");
    }
    // both appends landed despite the injected EINTR
    let ledger = Ledger::open(&path).unwrap();
    assert_eq!(ledger.stats().records, 2);
    assert_eq!(ledger.stats().recovered_tail_bytes, 0);
}

#[test]
fn ledger_append_kill_leaves_a_recoverable_torn_frame() {
    let _lock = chaos_lock();
    let cfg = tiny();
    let jobs = kmeans_pair();
    let path = tmpfile("ledger_torn.mllg");
    {
        let _armed = Armed::new("ledger-append-kill@2");
        let mut ledger = Ledger::open(&path).unwrap();
        let err = run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap_err();
        assert!(err.to_string().contains("injected crash mid-append"), "{err:?}");
    }
    // reopen: the torn second frame is truncated away, the first record
    // survives, and a resume re-executes only the lost cell
    let mut ledger = Ledger::open(&path).unwrap();
    let stats = ledger.stats();
    assert_eq!(stats.records, 1, "first append survives the crash");
    assert!(stats.recovered_tail_bytes > 0, "torn frame undetected");

    let report = run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
    assert!(report.failed.is_empty());
    assert_eq!(report.cached_cells, 1, "surviving record serves its cell");
    assert_eq!(report.workload_executions, 1, "only the lost cell re-runs");
    assert_eq!(report.outputs.len(), 2);
    assert_eq!(ledger.stats().records, 2);
}

#[test]
fn compaction_kill_is_crash_atomic() {
    let _lock = chaos_lock();
    let cfg = tiny();
    let jobs = kmeans_pair();
    let path = tmpfile("ledger_compact_kill.mllg");
    fault::install(None);
    let mut ledger = Ledger::open(&path).unwrap();
    run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
    // a superseding duplicate gives the compaction something to drop
    let dup = ledger.records()[0].clone();
    ledger.append(dup).unwrap();
    assert_eq!(ledger.stats().records, 3);
    assert_eq!(ledger.stats().unique, 2);

    {
        let _armed = Armed::new("ledger-compact-kill@1");
        let err = ledger.compact().unwrap_err().to_string();
        assert!(err.contains("injected crash"), "{err}");
    }
    drop(ledger);

    // the kill hit between temp-file write and rename: the original
    // ledger is byte-intact (all three records, no torn tail)
    let mut ledger = Ledger::open(&path).unwrap();
    let stats = ledger.stats();
    assert_eq!(stats.records, 3, "original ledger must be untouched");
    assert_eq!(stats.unique, 2);
    assert_eq!(stats.recovered_tail_bytes, 0);

    // a clean retry compacts, and zero cells are lost: a warm run
    // still answers the whole grid from the ledger
    let report = ledger.compact().unwrap();
    assert_eq!(report.records_before, 3);
    assert_eq!(report.records_after, 2);
    let mut ledger = Ledger::open(&path).unwrap();
    assert_eq!(ledger.stats().records, 2);
    let warm = run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
    assert_eq!(warm.cached_cells, 2, "compaction lost a cell");
    assert_eq!(warm.workload_executions, 0);
}

/// `grid --sweep cache` against `path`: one KMeans execution prices all
/// 40 geometries, each ledgered — the cheapest real CLI crash/resume.
fn sweep_cmd(path: &std::path::Path) -> Command {
    let mut c = bin();
    c.args(["grid", "--sweep", "cache", "--workload", "KMeans"]);
    c.args(["--scale", "0.02", "--iterations", "1"]);
    c.args(["--threads", "1", "--ledger"]);
    c.arg(path);
    c
}

#[test]
fn cli_grid_kill_and_resume_serves_completed_cells() {
    let _lock = chaos_lock();
    let path = tmpfile("sweep_kill.mllg");

    // run 1: hard-killed (process abort) after the second ledger append
    let killed = sweep_cmd(&path).args(["--chaos", "grid-kill@2"]).output().unwrap();
    assert!(!killed.status.success(), "grid-kill must abort the run");
    let survivors = Ledger::open(&path).unwrap().stats().records;
    assert_eq!(survivors, 2, "exactly the pre-kill appends survive");

    // run 2: resume — the killed run's cells come from the ledger and
    // the workload re-executes once for the missing geometries
    let resumed = sweep_cmd(&path).output().unwrap();
    let stdout = String::from_utf8_lossy(&resumed.stdout);
    assert!(resumed.status.success(), "resume failed: {stdout}");
    assert!(stdout.contains("2 cached"), "survivors not cached: {stdout}");

    // run 3: fully warmed — nothing executes, and the CLI certifies it
    let third = sweep_cmd(&path).arg("--assert-cached").output().unwrap();
    let stdout = String::from_utf8_lossy(&third.stdout);
    assert!(third.status.success(), "warm sweep not all-cached: {stdout}");
    assert!(stdout.contains("0 workload executions"), "{stdout}");
}

fn replay_out(trace: &std::path::Path) -> std::process::Output {
    let mut c = bin();
    c.args(["replay", "--trace"]).arg(trace);
    c.output().unwrap()
}

#[test]
fn cli_missing_and_empty_traces_fail_with_typed_errors() {
    let missing = tmpfile("definitely-missing.mlt");
    let out = replay_out(&missing);
    assert_eq!(out.status.code(), Some(2), "missing trace must error out");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("error:"), "{stderr}");
    assert!(stderr.contains("trace file not found"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
    assert!(!stderr.contains("RUST_BACKTRACE"), "{stderr}");

    let empty = tmpfile("empty.mlt");
    std::fs::write(&empty, b"").unwrap();
    let out = replay_out(&empty);
    assert_eq!(out.status.code(), Some(2), "empty trace must error out");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("empty trace file"), "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn cli_vacuous_gate_is_rejected_by_default() {
    let baseline = tmpfile("empty_baseline.json");
    let placeholder = GridResults::from_outputs(&tiny(), &[]);
    placeholder.save(&baseline).unwrap();

    let mut cmd = bin();
    cmd.args(["report", "--baseline"]).arg(&baseline);
    cmd.arg("--gate");
    let out = cmd.output().unwrap();
    assert_eq!(out.status.code(), Some(2), "vacuous gate must not pass");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("vacuous"), "{stderr}");

    let mut cmd = bin();
    cmd.args(["report", "--baseline"]).arg(&baseline);
    cmd.args(["--gate", "--allow-vacuous"]);
    let out = cmd.output().unwrap();
    assert!(out.status.success(), "--allow-vacuous must accept the no-op");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("VACUOUS"), "still loudly flagged: {stderr}");
}

#[test]
fn cli_rejects_malformed_chaos_specs() {
    let out = bin().args(["list", "--chaos", "flux@1"]).output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("chaos spec"), "{stderr}");
    assert!(stderr.contains("unknown site"), "{stderr}");
}
