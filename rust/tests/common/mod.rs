//! Shared scaffolding for the integration-test suites: seeded capture
//! builders, tiny experiment configs, per-suite temp files, scenario
//! fixtures, and `Metrics` comparison helpers. Lives in
//! `tests/common/mod.rs` (not `tests/common.rs`) so cargo does not
//! compile it as a test crate of its own; each suite pulls it in with
//! `mod common;`.
#![allow(dead_code)]

use mlperf::coordinator::{capture_trace, ExperimentConfig, Job, RecordedRun, Scenario};
use mlperf::sim::Metrics;
use mlperf::workloads::{by_name, LibraryProfile, RunContext, Workload};

/// The standard integration-test config: small enough for debug-build
/// `cargo test`, large enough that every workload emits a non-trivial
/// trace (the suites assert event counts to guard against silently
/// simulating nothing).
pub fn tiny() -> ExperimentConfig {
    ExperimentConfig { scale: 0.02, iterations: 1, ..Default::default() }
}

/// [`tiny`] pinned to a specific library profile.
pub fn tiny_profile(profile: LibraryProfile) -> ExperimentConfig {
    ExperimentConfig { profile, ..tiny() }
}

/// The single-iteration run context direct `Workload::run` harnesses use.
pub fn run_ctx() -> RunContext {
    RunContext { iterations: 1, ..Default::default() }
}

/// A fresh path under a per-suite temp directory. Any stale file from a
/// previous run is removed so tests never read leftovers.
pub fn tmpfile(suite: &str, name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mlperf-{suite}-tests"));
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    let _ = std::fs::remove_file(&p);
    p
}

/// Look a workload up by name, panicking with the name on failure.
pub fn workload(name: &str) -> Box<dyn Workload> {
    by_name(name).unwrap_or_else(|| panic!("unknown workload {name:?}"))
}

/// Record an in-memory capture of `name` under `cfg` — the seeded
/// builder every replay/broadcast/sampling suite starts from.
pub fn capture(name: &str, cfg: &ExperimentConfig, sw_prefetch: bool) -> RecordedRun {
    capture_trace(workload(name).as_ref(), cfg, sw_prefetch)
}

/// The mixed scenario fixture: replayable columns sharing one capture
/// per workload, a prefetch-variant cell, and a non-replayable
/// multicore cell — the shape the scheduler/ledger gates exercise.
pub fn scenario_jobs() -> Vec<Job> {
    vec![
        Job::new("KMeans", Scenario::Baseline),
        Job::new("KMeans", Scenario::PerfectL2),
        Job::new("KMeans", Scenario::PerfectLlc),
        Job::new("KMeans", Scenario::NoHwPrefetch),
        Job::new("KNN", Scenario::SwPrefetch),
        Job::new("GMM", Scenario::Multicore(2)),
    ]
}

/// Bit-exact `Metrics` equality with a labelled panic. The simulator is
/// deterministic, so parity gates compare whole structs — any field
/// drifting is a real divergence, not noise.
pub fn assert_metrics_eq(a: &Metrics, b: &Metrics, what: &str) {
    assert_eq!(a, b, "{what}: Metrics diverged");
}

/// Relative closeness for estimator checks: |a - b| <= tol * max(|b|, eps).
pub fn assert_rel_close(a: f64, b: f64, tol: f64, what: &str) {
    let scale = b.abs().max(1e-12);
    assert!(
        (a - b).abs() <= tol * scale,
        "{what}: {a} vs {b} differ by more than {:.2}% (rel {:.4})",
        tol * 100.0,
        (a - b).abs() / scale
    );
}
