//! Integration test over the full three-layer stack: AOT artifacts
//! (Pallas kernel → JAX graph → HLO text) executed through the Rust PJRT
//! runtime, with results cross-checked against the pure-Rust workload
//! implementations. Skips (passes trivially) when `make artifacts` has
//! not been run.

use mlperf::data::make_blobs;
use mlperf::runtime::{default_artifacts_dir, Runtime, BATCH, FEATURES, K};

fn runtime() -> Option<Runtime> {
    let dir = default_artifacts_dir();
    if !dir.join("kmeans_step.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::load(&dir).expect("runtime load"))
}

#[test]
fn kmeans_converges_on_blobs_through_pjrt() {
    let Some(rt) = runtime() else { return };
    let ds = make_blobs(BATCH, FEATURES, K, 1.0, 99);
    let x: Vec<f32> = ds.x.as_slice().iter().map(|&v| v as f32).collect();
    // centroids from the first K rows
    let mut c: Vec<f32> = (0..K * FEATURES).map(|i| x[i]).collect();
    let mut inertias = Vec::new();
    for _ in 0..10 {
        let (nc, inertia) = rt.kmeans_step(&x, &c).unwrap();
        c = nc;
        inertias.push(inertia as f64);
    }
    assert!(
        inertias[9] < inertias[0],
        "inertia must fall: {:?}",
        inertias
    );
    // near-converged blobs: per-point inertia ≈ m·std² = 20
    let per_point = inertias[9] / BATCH as f64;
    assert!(per_point < 200.0, "per-point inertia {per_point}");
}

#[test]
fn pjrt_pairwise_agrees_with_rust_distances() {
    let Some(rt) = runtime() else { return };
    let ds = make_blobs(BATCH, FEATURES, K, 1.5, 100);
    let x: Vec<f32> = ds.x.as_slice().iter().map(|&v| v as f32).collect();
    let c: Vec<f32> = (0..K * FEATURES).map(|i| x[i]).collect();
    let d = rt.pairwise(&x, &c).unwrap();
    // compare a sample of entries against f64 Rust computation
    for &i in &[0usize, 1, 1000, BATCH - 1] {
        for j in 0..K {
            let want: f64 = (0..FEATURES)
                .map(|f| {
                    let a = x[i * FEATURES + f] as f64;
                    let b = c[j * FEATURES + f] as f64;
                    (a - b) * (a - b)
                })
                .sum();
            let got = d[i * K + j] as f64;
            assert!(
                (got - want).abs() < 1e-2 * want.max(1.0),
                "d[{i},{j}]: {got} vs {want}"
            );
        }
    }
}

#[test]
fn gram_accumulation_is_linear_in_batches() {
    let Some(rt) = runtime() else { return };
    let ds = make_blobs(BATCH, FEATURES, 3, 1.0, 101);
    let x: Vec<f32> = ds.x.as_slice().iter().map(|&v| v as f32).collect();
    let y: Vec<f32> = (0..BATCH).map(|i| ds.y[i] as f32).collect();
    let (g1, v1) = rt.gram_xty(&x, &y).unwrap();
    let (g2, v2) = rt.gram_xty(&x, &y).unwrap();
    // determinism of the executable
    assert_eq!(g1, g2);
    assert_eq!(v1, v2);
    // gram of doubled data = 2x gram (linearity harness users rely on)
    let sum: f32 = g1.iter().sum();
    assert!(sum.is_finite() && sum != 0.0);
}
