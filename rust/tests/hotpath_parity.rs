//! Hot-path parity: the packed-set/MRU-filter cache ([`mlperf::sim::Cache`])
//! must be bit-identical — in `CacheStats`, `PrefetchStats`, DRAM traffic,
//! and full `Metrics` — to the seed-layout reference
//! ([`mlperf::sim::RefCache`], the probe path exactly as PR 2 shipped it)
//! across randomized traces, prefetch on/off, perfect-L2/LLC idealizations,
//! and multi-line accesses. The reference rides the *identical* hierarchy
//! and timeline code, so any divergence is the packed layout's fault.

use mlperf::sim::{
    run_multicore, run_multicore_with_model, BlockAccess, CpuConfig, Hierarchy, HierarchyConfig,
    PipelineSim, RefCache, RefHierarchy, RefPipelineSim,
};
use mlperf::trace::{BlockSink, Event, EventBlock, Recorder, Sink};
use mlperf::util::Pcg64;
use mlperf::workloads::{by_name, RunContext};

/// Random mixed event stream with multi-line loads/stores.
fn random_events(rng: &mut Pcg64, n: usize) -> Vec<Event> {
    (0..n)
        .map(|_| match rng.below(7) {
            0 => Event::Compute { int_ops: rng.below(6) as u32, fp_ops: rng.below(6) as u32 },
            1 => Event::Serial { ops: 1 + rng.below(4) as u32 },
            2 => Event::Load {
                addr: rng.below(1 << 30),
                size: 1 + rng.below(512) as u32,
                feeds_branch: rng.next_f64() < 0.2,
            },
            3 => Event::Store { addr: rng.below(1 << 30), size: 1 + rng.below(256) as u32 },
            4 => Event::Branch {
                site: rng.below(64) as u32,
                taken: rng.next_f64() < 0.5,
                conditional: rng.next_f64() < 0.9,
            },
            5 => Event::LoopBranch { site: rng.below(32) as u32, count: 1 + rng.below(30) as u32 },
            _ => Event::SwPrefetch { addr: rng.below(1 << 30) },
        })
        .collect()
}

/// The scenario grid of the acceptance criteria: hw-prefetch on/off ×
/// {real, perfect-L2, perfect-LLC}.
fn scenario_grid() -> Vec<CpuConfig> {
    let mut out = Vec::new();
    for hw_prefetch in [true, false] {
        for (perfect_l2, perfect_llc) in [(false, false), (true, false), (false, true)] {
            let mut cfg = CpuConfig::default();
            cfg.cache.hw_prefetch = hw_prefetch;
            cfg.cache.perfect_l2 = perfect_l2;
            cfg.cache.perfect_llc = perfect_llc;
            out.push(cfg);
        }
    }
    out
}

/// Feed events through the block lane (Recorder-equivalent delivery).
fn consume_blocks<S: BlockSink>(sink: &mut S, events: &[Event]) {
    let mut block = EventBlock::with_capacity();
    for &ev in events {
        block.push_event(ev);
        if block.is_full() {
            sink.consume(&block);
            block.clear();
        }
    }
    if !block.is_empty() {
        sink.consume(&block);
    }
    sink.finalize();
}

/// Randomized-trace property: packed (block lane) vs seed reference
/// (per-event lane) produce bit-identical `Metrics` — which embeds the
/// instruction mix, miss ratios, branch, DRAM, and `PrefetchStats` — and
/// bit-identical per-level `CacheStats`, on every scenario of the grid.
#[test]
fn metrics_bit_identical_across_scenarios() {
    for (case, cfg) in scenario_grid().into_iter().enumerate() {
        let mut rng = Pcg64::new(0x9ACC ^ (case as u64 * 0x9E37_79B9));
        let events = random_events(&mut rng, 30_000);

        let mut packed = PipelineSim::new(cfg.clone());
        consume_blocks(&mut packed, &events);

        let mut reference = RefPipelineSim::with_cache_model(cfg.clone());
        for &ev in &events {
            reference.event(ev);
        }
        Sink::finish(&mut reference);

        assert_eq!(packed.metrics(), reference.metrics(), "metrics diverged in scenario {case}");
        assert_eq!(
            packed.hierarchy.l1.stats, reference.hierarchy.l1.stats,
            "L1 stats diverged in scenario {case}"
        );
        assert_eq!(
            packed.hierarchy.l2.stats, reference.hierarchy.l2.stats,
            "L2 stats diverged in scenario {case}"
        );
        assert_eq!(
            packed.hierarchy.l3.stats, reference.hierarchy.l3.stats,
            "L3 stats diverged in scenario {case}"
        );
        assert_eq!(packed.hierarchy.pf_stats, reference.hierarchy.pf_stats);
    }
}

/// Step-level property: every access returns the same serving level and
/// appends the same DRAM requests, under a small thrash-prone hierarchy
/// (maximal eviction/back-invalidation pressure on the packed layout).
#[test]
fn hierarchy_levels_and_dram_traffic_identical_per_access() {
    let cfg = HierarchyConfig {
        l1_bytes: 1024,
        l1_ways: 2,
        l2_bytes: 4096,
        l2_ways: 4,
        l3_bytes: 16384,
        l3_ways: 4,
        hw_prefetch: true,
        perfect_l2: false,
        perfect_llc: false,
    };
    let mut packed = Hierarchy::new(&cfg);
    let mut reference = RefHierarchy::with_model(&cfg);
    let mut rng = Pcg64::new(0xCAFE);
    let (mut dram_p, mut dram_r) = (Vec::new(), Vec::new());
    for step in 0..50_000 {
        let addr = rng.below(1 << 22);
        let size = 1 + rng.below(192) as u32;
        let store = rng.next_f64() < 0.3;
        if rng.next_f64() < 0.05 {
            packed.sw_prefetch(addr, &mut dram_p);
            reference.sw_prefetch(addr, &mut dram_r);
        }
        let got_p = packed.access(addr, size, store, &mut dram_p);
        let got_r = reference.access(addr, size, store, &mut dram_r);
        assert_eq!(got_p, got_r, "level diverged at step {step}");
        assert_eq!(dram_p, dram_r, "dram traffic diverged at step {step}");
        dram_p.clear();
        dram_r.clear();
    }
    assert_eq!(packed.l1.stats, reference.l1.stats);
    assert_eq!(packed.l2.stats, reference.l2.stats);
    assert_eq!(packed.l3.stats, reference.l3.stats);
    assert_eq!(packed.pf_stats, reference.pf_stats);
}

/// Real-workload traces agree too (block lane on both sides).
#[test]
fn workload_metrics_bit_identical() {
    for name in ["KMeans", "KNN"] {
        let w = by_name(name).unwrap();
        let ds = w.make_dataset(400, 8, 0x5EED);
        let ctx = RunContext { iterations: 1, ..Default::default() };

        let mut packed = PipelineSim::new(CpuConfig::default());
        {
            let mut rec = Recorder::new(&mut packed, 7);
            w.run(&ds, &ctx, &mut rec);
            rec.finish();
        }
        let mut reference = RefPipelineSim::with_cache_model(CpuConfig::default());
        {
            let mut rec = Recorder::new(&mut reference, 7);
            w.run(&ds, &ctx, &mut rec);
            rec.finish();
        }
        assert_eq!(packed.metrics(), reference.metrics(), "{name} diverged");
    }
}

/// Multicore sharding/aggregation is cache-model independent.
#[test]
fn multicore_aggregate_bit_identical() {
    let mut rng = Pcg64::new(0x4C0E);
    let addrs: Vec<u64> = (0..10_000).map(|_| rng.below(1 << 26) & !7).collect();
    let drive = |_c: usize, rec: &mut Recorder| {
        for &a in &addrs {
            rec.load(a, 8);
            rec.compute(2, 1);
        }
    };
    let base = CpuConfig::default();
    let packed = run_multicore(&base, 4, 9, drive);
    let reference = run_multicore_with_model::<RefCache, _>(&base, 4, 9, drive);
    assert_eq!(packed, reference);
}

/// The cache-only block lane (`Hierarchy::access_block`) replays a
/// block's memory lanes exactly like per-event access calls.
#[test]
fn access_block_matches_per_event_accesses() {
    let cfg = HierarchyConfig::default();
    let mut rng = Pcg64::new(0xB10C2);
    let events = random_events(&mut rng, 20_000);

    let mut batch = Hierarchy::new(&cfg);
    let mut dram_b = Vec::new();
    let mut block = EventBlock::with_capacity();
    let mut summary = BlockAccess::default();
    for &ev in &events {
        block.push_event(ev);
        if block.is_full() {
            let s = batch.access_block(&block, &mut dram_b);
            summary.accesses += s.accesses;
            summary.dram_lines += s.dram_lines;
            block.clear();
        }
    }
    if !block.is_empty() {
        let s = batch.access_block(&block, &mut dram_b);
        summary.accesses += s.accesses;
        summary.dram_lines += s.dram_lines;
    }

    let mut single = Hierarchy::new(&cfg);
    let mut dram_s = Vec::new();
    let (mut accesses, mut dram_lines) = (0u64, 0u64);
    for &ev in &events {
        match ev {
            Event::Load { addr, size, .. } => {
                accesses += 1;
                dram_lines += single.access(addr, size, false, &mut dram_s).1 as u64;
            }
            Event::Store { addr, size } => {
                accesses += 1;
                dram_lines += single.access(addr, size, true, &mut dram_s).1 as u64;
            }
            Event::SwPrefetch { addr } => single.sw_prefetch(addr, &mut dram_s),
            _ => {}
        }
    }

    assert_eq!(summary.accesses, accesses);
    assert_eq!(summary.dram_lines, dram_lines);
    assert_eq!(dram_b, dram_s);
    assert_eq!(batch.l1.stats, single.l1.stats);
    assert_eq!(batch.l2.stats, single.l2.stats);
    assert_eq!(batch.l3.stats, single.l3.stats);
    assert_eq!(batch.pf_stats, single.pf_stats);
}
