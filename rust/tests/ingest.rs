//! Staged-ingest and fan-out-scheduling parity tests: the pipelined
//! trace reader must deliver the *identical block sequence* (and
//! therefore bit-identical `Metrics`) as the synchronous path for real
//! workload traces under scenario mutations, and the intra-capture
//! fan-out grid scheduler must be output-identical to the grouped
//! scheduler and to direct execution.

use mlperf::coordinator::{
    characterize_with, record_characterize, replay_file, run_jobs, run_jobs_replayed,
    run_jobs_replayed_grouped, ExperimentConfig, Job, Scenario,
};
use mlperf::sim::CpuConfig;
use mlperf::trace::{BlockPool, BlockSink, EventBlock, PipelinedIngest, ReplaySource};

mod common;

fn tiny() -> ExperimentConfig {
    common::tiny()
}

fn tmpfile(name: &str) -> std::path::PathBuf {
    common::tmpfile("ingest", name)
}

/// Sink cloning every delivered block: the strongest parity witness —
/// same blocks, same boundaries, same order.
#[derive(Default)]
struct BlockLog {
    blocks: Vec<EventBlock>,
    finalized: bool,
}

impl BlockSink for BlockLog {
    fn consume(&mut self, block: &EventBlock) {
        self.blocks.push(block.clone());
    }
    fn finalize(&mut self) {
        self.finalized = true;
    }
}

#[test]
fn pipelined_ingest_is_bit_identical_for_real_workloads_and_scenarios() {
    let cfg = tiny();
    let scenarios: [(&str, fn(&mut CpuConfig)); 2] = [
        ("perfect-l2", |c| c.cache.perfect_l2 = true),
        ("no-hw-prefetch", |c| c.cache.hw_prefetch = false),
    ];
    for name in ["KMeans", "KNN", "Decision Tree"] {
        let w = common::workload(name);
        let path = tmpfile(&format!("{}.mlt", name.replace(' ', "_")));
        record_characterize(w.as_ref(), &cfg, false, &path).unwrap();

        // block-sequence parity, independent of any simulator
        let mut sync_log = BlockLog::default();
        ReplaySource::open(&path).unwrap().replay_into(&mut sync_log).unwrap();
        let mut pipe_log = BlockLog::default();
        let stats =
            PipelinedIngest::open(&path, 3).unwrap().replay_into(&mut pipe_log).unwrap();
        assert!(!sync_log.blocks.is_empty(), "{name}: trivial trace");
        assert_eq!(
            sync_log.blocks, pipe_log.blocks,
            "{name}: pipelined ingest altered the block sequence"
        );
        assert_eq!(stats.blocks as usize, pipe_log.blocks.len());
        assert!(sync_log.finalized && pipe_log.finalized);

        // Metrics parity under scenario mutations, vs direct execution
        for (scenario, mutate) in scenarios {
            let direct = characterize_with(w.as_ref(), &cfg, false, None, None, mutate);
            let sync_cfg = ExperimentConfig { ingest_threads: 1, ..tiny() };
            let (_, sync_m, _) = replay_file(&path, &sync_cfg, mutate).unwrap();
            for threads in [0usize, 2, 4] {
                let pipe_cfg = ExperimentConfig { ingest_threads: threads, ..tiny() };
                let (_, pipe_m, _) = replay_file(&path, &pipe_cfg, mutate).unwrap();
                assert_eq!(
                    pipe_m, sync_m,
                    "{name}/{scenario}: pipelined ({threads} threads) != synchronous"
                );
            }
            assert_eq!(
                sync_m, direct.metrics,
                "{name}/{scenario}: replay != direct execution"
            );
        }
    }
}

#[test]
fn block_pool_recycles_cleared() {
    let pool = BlockPool::new();
    let mut b = pool.get_block();
    b.push_load(0x40, 8, false);
    b.push_store(0x80, 16);
    b.push_prefetch(0x1000);
    pool.put_block(b);
    let b = pool.get_block();
    assert!(b.is_empty(), "recycled block must be cleared");
    assert!(
        b.loads.is_empty() && b.stores.is_empty() && b.prefetches.is_empty(),
        "every lane must be cleared"
    );
    assert_eq!(b.iter().count(), 0);
}

#[test]
fn fanout_scheduler_matches_grouped_and_direct() {
    let cfg = tiny();
    // few workloads × many scenario columns (the convoy shape), plus a
    // prefetch-variant cell and a non-replayable multicore cell
    let mut jobs: Vec<Job> = Vec::new();
    for w in ["KMeans", "KNN"] {
        for s in [
            Scenario::Baseline,
            Scenario::PerfectL2,
            Scenario::PerfectLlc,
            Scenario::NoHwPrefetch,
            Scenario::DramIdealRows,
        ] {
            jobs.push(Job::new(w, s));
        }
    }
    jobs.push(Job::new("KMeans", Scenario::SwPrefetch));
    jobs.push(Job::new("GMM", Scenario::Multicore(2)));

    let direct = run_jobs(&cfg, &jobs, 2);
    let grouped = run_jobs_replayed_grouped(&cfg, &jobs, 3);
    let fanout = run_jobs_replayed(&cfg, &jobs, 4);

    // 2 captures (5 cells each) + SwPrefetch single-cell direct +
    // multicore direct = 4 executions in both replay modes
    assert_eq!(grouped.workload_executions, 4);
    assert_eq!(fanout.workload_executions, 4);
    assert_eq!(fanout.outputs.len(), jobs.len());

    for ((d, g), f) in direct.outputs.iter().zip(&grouped.outputs).zip(&fanout.outputs) {
        assert_eq!(d.job, g.job);
        assert_eq!(d.job, f.job, "output order must equal input order");
        assert_eq!(d.metrics, g.metrics, "grouped diverged for {:?}", d.job);
        assert_eq!(d.metrics, f.metrics, "fan-out diverged for {:?}", d.job);
        assert_eq!(d.quality, f.quality);
    }
}

#[test]
fn fanout_scheduler_handles_single_thread_and_many_threads() {
    let cfg = tiny();
    let jobs = vec![
        Job::new("KMeans", Scenario::Baseline),
        Job::new("KMeans", Scenario::PerfectL2),
        Job::new("KMeans", Scenario::PerfectLlc),
        Job::new("KMeans", Scenario::DramIdealRows),
    ];
    let one = run_jobs_replayed(&cfg, &jobs, 1);
    assert_eq!(one.workload_executions, 1);
    let many = run_jobs_replayed(&cfg, &jobs, 16);
    assert_eq!(many.workload_executions, 1);
    for (a, b) in one.outputs.iter().zip(&many.outputs) {
        assert_eq!(a.job, b.job);
        assert_eq!(a.metrics, b.metrics);
    }
}

#[test]
fn ingest_threads_knob_never_changes_replay_results() {
    let cfg = tiny();
    let w = common::workload("GMM");
    let path = tmpfile("gmm_knob.mlt");
    record_characterize(w.as_ref(), &cfg, false, &path).unwrap();
    let mut reference = None;
    for threads in [1usize, 2, 3, 8] {
        let c = ExperimentConfig { ingest_threads: threads, ..tiny() };
        let (_, m, stats) = replay_file(&path, &c, |_| {}).unwrap();
        assert!(stats.events > 0);
        match &reference {
            None => reference = Some(m),
            Some(r) => assert_eq!(&m, r, "ingest_threads={threads} changed Metrics"),
        }
    }
}
