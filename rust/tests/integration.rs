//! Cross-module integration tests: workloads → trace → simulators →
//! metrics, plus the paper's headline qualitative claims at reduced scale.
//!
//! These assert the *shape* results the paper reports (who is worse than
//! whom, which optimization helps which category) rather than absolute
//! values — the contract EXPERIMENTS.md documents.

use mlperf::coordinator::*;
use mlperf::reorder::ReorderKind;
use mlperf::workloads::{by_name, registry, Category, LibraryProfile};

fn cfg(scale: f64) -> ExperimentConfig {
    ExperimentConfig { scale, iterations: 1, ..Default::default() }
}

#[test]
fn all_workloads_characterize_without_panicking() {
    let c = cfg(0.02);
    for w in registry() {
        let ch = characterize(w.as_ref(), &c);
        assert!(ch.metrics.cycles > 0.0, "{}", w.name());
        assert!(ch.metrics.cpi.is_finite(), "{}", w.name());
        let sum = ch.metrics.retiring_pct
            + ch.metrics.bad_spec_pct
            + ch.metrics.core_bound_pct
            + ch.metrics.mem_bound_pct;
        assert!(sum <= 105.0, "{}: top-down sum {sum}", w.name());
    }
}

/// Paper Section III: tree-based workloads dominate bad speculation.
#[test]
fn tree_workloads_have_highest_bad_spec() {
    let c = cfg(0.06);
    let mut tree = Vec::new();
    let mut other = Vec::new();
    for w in registry() {
        let m = characterize(w.as_ref(), &c).metrics;
        match w.category() {
            Category::TreeBased => tree.push(m.bad_spec_pct),
            _ => other.push(m.bad_spec_pct),
        }
    }
    let tree_mean = tree.iter().sum::<f64>() / tree.len() as f64;
    let other_mean = other.iter().sum::<f64>() / other.len() as f64;
    assert!(
        tree_mean > 2.0 * other_mean,
        "tree bad-spec {tree_mean:.1}% must dominate others {other_mean:.1}%"
    );
}

/// Paper Fig. 9: matrix workloads burn far more bandwidth than the rest.
#[test]
fn matrix_workloads_have_higher_bandwidth_utilization() {
    let c = cfg(0.06);
    let bw = |name: &str| {
        let w = by_name(name).unwrap();
        characterize(w.as_ref(), &c).metrics.bandwidth_utilization_pct()
    };
    let matrix = (bw("Ridge") + bw("SVM-RBF")) / 2.0;
    let tree = (bw("Decision Tree") + bw("Adaboost")) / 2.0;
    assert!(
        matrix > tree,
        "matrix bw {matrix:.1}% should exceed tree bw {tree:.1}%"
    );
}

/// Paper Fig. 13: irregular workloads waste hardware prefetches.
#[test]
fn irregular_workloads_waste_more_hw_prefetches() {
    let c = cfg(0.06);
    let useless = |name: &str| {
        let w = by_name(name).unwrap();
        characterize(w.as_ref(), &c)
            .metrics
            .prefetch
            .hw_useless_fraction()
    };
    let knn = useless("KNN");
    let ridge = useless("Ridge");
    assert!(
        knn > ridge,
        "KNN useless-prefetch {knn:.2} should exceed Ridge {ridge:.2}"
    );
    assert!(knn > 0.2, "KNN should waste a large fraction: {knn:.2}");
}

/// Paper Fig. 12: perfect caches buy meaningful IPC on memory-bound
/// workloads.
#[test]
fn perfect_l2_buys_ipc_on_neighbour_workloads() {
    let c = cfg(0.06);
    let w = by_name("DBSCAN").unwrap();
    let s = perfect_cache_study(w.as_ref(), &c);
    let gain = s.perfect_l2.ipc / s.base.ipc;
    assert!(gain > 1.1, "perfect L2 should buy >10% IPC on DBSCAN: {gain:.3}");
}

/// Paper Fig. 18: software prefetching speeds up neighbour/tree
/// workloads without changing their results.
#[test]
fn sw_prefetch_speeds_up_knn() {
    let c = cfg(0.08);
    let w = by_name("KNN").unwrap();
    let s = prefetch_study(w.as_ref(), &c);
    assert_eq!(s.base_quality, s.prefetched_quality);
    let speedup = s.prefetched.speedup_vs(&s.base);
    assert!(
        speedup > 1.0,
        "KNN should speed up under SW prefetch: {speedup:.3}"
    );
    assert!(
        s.prefetched.l2_miss_ratio <= s.base.l2_miss_ratio,
        "L2 miss ratio should not rise: {} -> {}",
        s.base.l2_miss_ratio,
        s.prefetched.l2_miss_ratio
    );
}

/// Paper Figs. 20/23: data-layout reordering improves row-buffer hit
/// ratio and end-to-end cycles for irregular workloads.
#[test]
fn zorder_layout_helps_knn_dram_behaviour() {
    let c = cfg(0.08);
    let w = by_name("KNN").unwrap();
    let s = reorder_study(w.as_ref(), ReorderKind::ZOrder, &c);
    assert!(
        s.reordered.dram.row_hit_ratio() > s.baseline.dram.row_hit_ratio(),
        "row-buffer hit ratio should improve: {:.3} -> {:.3}",
        s.baseline.dram.row_hit_ratio(),
        s.reordered.dram.row_hit_ratio()
    );
    assert!(
        s.speedup_no_overhead() > 1.0,
        "Z-order layout should speed KNN up: {:.3}",
        s.speedup_no_overhead()
    );
}

/// Paper Table VII: ideal row buffer lowers average access latency.
#[test]
fn ideal_row_buffer_reduces_latency() {
    let c = cfg(0.06);
    for name in ["KNN", "Adaboost"] {
        let w = by_name(name).unwrap();
        let real = dram_study(w.as_ref(), &c, false);
        let ideal = dram_study(w.as_ref(), &c, true);
        assert!(
            ideal.avg_latency_ns() < real.avg_latency_ns(),
            "{name}: {:.1} !< {:.1}",
            ideal.avg_latency_ns(),
            real.avg_latency_ns()
        );
    }
}

/// Paper Tables III/IV: the single-core conclusions persist at 4/8 cores.
#[test]
fn multicore_keeps_bottleneck_structure() {
    let c = cfg(0.04);
    let w = by_name("DBSCAN").unwrap();
    let m1 = multicore_characterize(w.as_ref(), &c, 1);
    let m4 = multicore_characterize(w.as_ref(), &c, 4);
    let m8 = multicore_characterize(w.as_ref(), &c, 8);
    for (n, m) in [(1, &m1), (4, &m4), (8, &m8)] {
        assert!(
            m.dram_bound_pct > 5.0,
            "{n}-core DBSCAN should stay DRAM-bound: {:.1}%",
            m.dram_bound_pct
        );
    }
}

/// Profiles differ: the mlpack profile executes fewer instructions for
/// the same work (leaner loops), as the paper's Figs. 1-2 imply.
#[test]
fn mlpack_profile_is_leaner() {
    let mut c = cfg(0.06);
    let w = by_name("KNN").unwrap();
    c.profile = LibraryProfile::Sklearn;
    let sk = characterize(w.as_ref(), &c).metrics;
    c.profile = LibraryProfile::Mlpack;
    let ml = characterize(w.as_ref(), &c).metrics;
    assert!(
        ml.instructions < sk.instructions,
        "mlpack should retire fewer instructions: {} vs {}",
        ml.instructions,
        sk.instructions
    );
    assert!(
        ml.cycles < sk.cycles,
        "mlpack should be faster end-to-end: {} vs {}",
        ml.cycles,
        sk.cycles
    );
}

/// Determinism: identical config ⇒ identical metrics (the reproducibility
/// contract of EXPERIMENTS.md).
#[test]
fn characterization_is_deterministic() {
    let c = cfg(0.03);
    let w = by_name("KMeans").unwrap();
    let a = characterize(w.as_ref(), &c).metrics;
    let b = characterize(w.as_ref(), &c).metrics;
    assert_eq!(a.instructions, b.instructions);
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.mix, b.mix);
}
