//! Experiment-ledger integration tests: simulate-once/query-many.
//!
//! The contract under test is the acceptance bar of the ledger
//! subsystem: running the same grid twice against one ledger executes
//! every cell exactly once (the second run is answered entirely from
//! disk, bit-identically), fingerprints are stable for identical
//! configurations and change for *any* config perturbation, and a
//! corrupted ledger tail loses only the records after the first bad
//! byte.

use mlperf::coordinator::{
    full_grid, run_jobs_ledgered, run_jobs_replayed, ExperimentConfig, Job, Scenario,
};
use mlperf::ledger::{cell_fingerprint, diff, GridResults, Ledger};
use mlperf::workloads::LibraryProfile;

mod common;

fn tiny() -> ExperimentConfig {
    common::tiny()
}

fn tmpfile(name: &str) -> std::path::PathBuf {
    common::tmpfile("ledger", name)
}

fn scenario_jobs() -> Vec<Job> {
    common::scenario_jobs()
}

#[test]
fn second_ledgered_run_executes_nothing_and_is_bit_identical() {
    let cfg = tiny();
    let jobs = scenario_jobs();
    let path = tmpfile("twice.mllg");

    let first = {
        let mut ledger = Ledger::open(&path).unwrap();
        run_jobs_ledgered(&cfg, &jobs, 2, &mut ledger).unwrap()
    };
    assert_eq!(first.cached_cells, 0, "cold ledger has nothing to offer");
    assert!(first.workload_executions > 0);
    assert_eq!(first.outputs.len(), jobs.len());

    // reopen from disk: the cache must survive the process boundary the
    // ledger file represents
    let second = {
        let mut ledger = Ledger::open(&path).unwrap();
        run_jobs_ledgered(&cfg, &jobs, 2, &mut ledger).unwrap()
    };
    assert_eq!(second.workload_executions, 0, "warm ledger must execute nothing");
    assert_eq!(second.cached_cells, jobs.len());
    for (a, b) in first.outputs.iter().zip(&second.outputs) {
        assert_eq!(a.job, b.job);
        assert_eq!(a.metrics, b.metrics, "cached metrics diverged for {:?}", a.job);
        assert_eq!(a.quality, b.quality);
    }
}

#[test]
fn ledgered_outputs_match_replayed_mode() {
    let cfg = tiny();
    let jobs = scenario_jobs();
    let path = tmpfile("parity.mllg");
    let mut ledger = Ledger::open(&path).unwrap();
    let ledgered = run_jobs_ledgered(&cfg, &jobs, 2, &mut ledger).unwrap();
    let replayed = run_jobs_replayed(&cfg, &jobs, 2);
    for (a, b) in ledgered.outputs.iter().zip(&replayed.outputs) {
        assert_eq!(a.job, b.job);
        assert_eq!(a.metrics, b.metrics, "ledgered diverged for {:?}", a.job);
        assert_eq!(a.quality, b.quality);
    }
}

#[test]
fn partial_warm_ledger_executes_only_the_new_cells() {
    let cfg = tiny();
    let path = tmpfile("incremental.mllg");
    let warm = vec![
        Job::new("KMeans", Scenario::Baseline),
        Job::new("KMeans", Scenario::PerfectL2),
    ];
    {
        let mut ledger = Ledger::open(&path).unwrap();
        run_jobs_ledgered(&cfg, &warm, 2, &mut ledger).unwrap();
    }
    let grown = vec![
        Job::new("KMeans", Scenario::Baseline),
        Job::new("KMeans", Scenario::PerfectL2),
        Job::new("KMeans", Scenario::PerfectLlc),
    ];
    let mut ledger = Ledger::open(&path).unwrap();
    let report = run_jobs_ledgered(&cfg, &grown, 2, &mut ledger).unwrap();
    assert_eq!(report.cached_cells, 2);
    assert_eq!(report.workload_executions, 1, "only the new scenario cell runs");
}

#[test]
fn any_config_change_invalidates_the_cache() {
    let base = tiny();
    let jobs = vec![Job::new("KMeans", Scenario::Baseline)];
    let path = tmpfile("invalidate.mllg");
    {
        let mut ledger = Ledger::open(&path).unwrap();
        run_jobs_ledgered(&base, &jobs, 1, &mut ledger).unwrap();
    }
    let variants: Vec<(&str, ExperimentConfig)> = vec![
        ("seed", ExperimentConfig { seed: 1, ..tiny() }),
        ("scale", ExperimentConfig { scale: 0.03, ..tiny() }),
        ("iterations", ExperimentConfig { iterations: 2, ..tiny() }),
        ("profile", ExperimentConfig { profile: LibraryProfile::Mlpack, ..tiny() }),
        (
            "mshrs",
            {
                let mut c = tiny();
                c.cpu.mshrs += 2;
                c
            },
        ),
        (
            "l3_bytes",
            {
                let mut c = tiny();
                c.cpu.cache.l3_bytes /= 2;
                c
            },
        ),
        (
            "dram timing",
            {
                let mut c = tiny();
                c.cpu.dram.t_cl += 1.0;
                c
            },
        ),
    ];
    for (name, cfg) in variants {
        let mut ledger = Ledger::open(&path).unwrap();
        let report = run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
        assert_eq!(
            report.cached_cells, 0,
            "changing {name} must miss the cache (fingerprint collision)"
        );
        assert_eq!(report.workload_executions, 1, "{name}");
    }
    // and the original config still hits
    let mut ledger = Ledger::open(&path).unwrap();
    let report = run_jobs_ledgered(&base, &jobs, 1, &mut ledger).unwrap();
    assert_eq!(report.cached_cells, 1);
}

#[test]
fn sampled_and_full_cells_never_cross_serve() {
    use mlperf::sim::SampleConfig;
    let full = tiny();
    let sampled =
        ExperimentConfig { sample: Some(SampleConfig { detail: 2, period: 16 }), ..tiny() };
    let jobs = vec![Job::new("KMeans", Scenario::Baseline)];
    let path = tmpfile("sampled.mllg");
    {
        let mut ledger = Ledger::open(&path).unwrap();
        let r = run_jobs_ledgered(&full, &jobs, 1, &mut ledger).unwrap();
        assert_eq!(r.cached_cells, 0);
    }
    // a sampled query must MISS the stored full-replay cell — an
    // estimate and an exact result are different contracts even when
    // the workload/scenario/config tuple is identical
    {
        let mut ledger = Ledger::open(&path).unwrap();
        let r = run_jobs_ledgered(&sampled, &jobs, 1, &mut ledger).unwrap();
        assert_eq!(r.cached_cells, 0, "sampled query served a full-replay cell");
        assert_eq!(r.workload_executions, 1);
        assert!(
            r.outputs[0].sample.is_some(),
            "freshly sampled cell must carry its CI diagnostics"
        );
    }
    // once both are stored, each mode hits its own cell (and a cached
    // sampled cell comes back without run-time CI diagnostics)
    let mut ledger = Ledger::open(&path).unwrap();
    let full_hit = run_jobs_ledgered(&full, &jobs, 1, &mut ledger).unwrap();
    assert_eq!(full_hit.cached_cells, 1, "full query must still hit the full cell");
    let sampled_hit = run_jobs_ledgered(&sampled, &jobs, 1, &mut ledger).unwrap();
    assert_eq!(sampled_hit.cached_cells, 1, "sampled query must hit the sampled cell");
    assert!(sampled_hit.outputs[0].sample.is_none(), "CI is run-time only, never ledgered");
    // and different sampling parameters are their own cells again
    let other =
        ExperimentConfig { sample: Some(SampleConfig { detail: 4, period: 64 }), ..tiny() };
    let r = run_jobs_ledgered(&other, &jobs, 1, &mut ledger).unwrap();
    assert_eq!(r.cached_cells, 0, "different sampling params must not alias");
}

#[test]
fn fingerprints_are_stable_across_ledger_reopen() {
    // The fingerprint stored in the file must equal one recomputed by a
    // fresh in-process canonicalization — the on-disk index survives
    // struct re-instantiation (the single-process stand-in for "across
    // process runs"; determinism has no hidden state to vary).
    let cfg = tiny();
    let job = Job::new("Ridge", Scenario::Baseline);
    let path = tmpfile("stable.mllg");
    {
        let mut ledger = Ledger::open(&path).unwrap();
        run_jobs_ledgered(&cfg, &[job.clone()], 1, &mut ledger).unwrap();
    }
    let ledger = Ledger::open(&path).unwrap();
    let fp = cell_fingerprint(&tiny(), &Job::new("Ridge", Scenario::Baseline));
    let rec = ledger.get(fp).expect("recomputed fingerprint must hit the stored record");
    assert_eq!(rec.provenance.workload, "Ridge");
    assert_eq!(rec.provenance.scenario, "baseline");
    assert!(rec.provenance.rows > 0);
    assert!(rec.metrics.instructions > 0);
}

#[test]
fn corrupted_tail_recovers_and_only_reexecutes_lost_cells() {
    let cfg = tiny();
    let jobs = vec![
        Job::new("KMeans", Scenario::Baseline),
        Job::new("KMeans", Scenario::PerfectL2),
        Job::new("KMeans", Scenario::PerfectLlc),
    ];
    let path = tmpfile("recover.mllg");
    {
        let mut ledger = Ledger::open(&path).unwrap();
        run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
        assert_eq!(ledger.stats().records, 3);
    }
    // tear the last record like a crashed append
    let len = std::fs::metadata(&path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(len - 7).unwrap();
    drop(f);

    let mut ledger = Ledger::open(&path).unwrap();
    assert_eq!(ledger.stats().records, 2, "two intact records survive the tear");
    assert!(ledger.stats().recovered_tail_bytes > 0);
    let report = run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
    assert_eq!(report.cached_cells, 2);
    assert_eq!(report.workload_executions, 1, "only the torn cell re-runs");
    drop(ledger);
    let ledger = Ledger::open(&path).unwrap();
    assert_eq!(ledger.stats().records, 3, "the re-run was appended durably");
    assert_eq!(ledger.stats().recovered_tail_bytes, 0);
}

#[test]
fn grid_results_roundtrip_and_self_diff_is_exact() {
    let cfg = tiny();
    let jobs = scenario_jobs();
    let report = run_jobs_replayed(&cfg, &jobs, 2);
    let current = GridResults::from_outputs(&cfg, &report.outputs);
    assert_eq!(current.cells.len(), jobs.len());

    let path = tmpfile("results.json");
    current.save(&path).unwrap();
    let loaded = GridResults::load(&path).unwrap();
    assert_eq!(loaded.scale, cfg.scale);
    assert_eq!(loaded.cells.len(), current.cells.len());

    // zero tolerance: JSON round-trips f64 shortest-form exactly, so a
    // diff of a run against its own serialization is *exactly* clean
    let report = diff(&current, &loaded, 0.0);
    assert!(report.pass(), "self-diff drifted: {:?}", report.rows.iter().find(|r| !r.within));
    assert_eq!(report.missing.len(), 0);

    // and a perturbed baseline is caught
    let mut drifted = loaded.clone();
    drifted.cells[0].metrics[0].1 *= 1.2;
    assert!(!diff(&current, &drifted, 0.01).pass());
}

#[test]
fn baseline_cells_parse_back_into_runnable_jobs() {
    // `mlperf report --baseline` rebuilds jobs from serialized scenario
    // strings — every scenario the full grid emits must round-trip
    let cfg = tiny();
    for job in full_grid(&cfg) {
        let rendered = job.scenario.to_string();
        assert_eq!(
            Scenario::parse(&rendered),
            Some(job.scenario),
            "scenario {rendered:?} does not round-trip"
        );
    }
}
