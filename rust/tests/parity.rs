//! Trace-parity tests: the same workload run through the legacy
//! per-event `dyn Sink` path (via the `PerEvent` adapter) and through the
//! native columnar block pipeline must emit the same number of events and
//! produce identical `Metrics` — bit-for-bit, since the two paths share
//! the per-event timeline handlers and all mix counters are integers.

use mlperf::sim::{CpuConfig, Metrics, PipelineSim};
use mlperf::trace::{PerEvent, Recorder};
use mlperf::workloads::{RunContext, Workload};

mod common;

fn ctx() -> RunContext {
    common::run_ctx()
}

/// Native path: Recorder -> EventBlock -> PipelineSim::consume.
fn run_block_path(w: &dyn Workload, rows: usize) -> (Metrics, u64) {
    let ds = w.make_dataset(rows, 8, 0x9A11);
    let mut sim = PipelineSim::new(CpuConfig::default());
    let events = {
        let mut rec = Recorder::new(&mut sim, 3);
        let _ = w.run(&ds, &ctx(), &mut rec);
        rec.finish();
        rec.events_emitted()
    };
    (sim.metrics(), events)
}

/// Legacy path: Recorder -> EventBlock -> PerEvent -> Sink::event, one
/// virtual call and enum match per event, exactly as the seed pipeline
/// dispatched.
fn run_legacy_path(w: &dyn Workload, rows: usize) -> (Metrics, u64) {
    let ds = w.make_dataset(rows, 8, 0x9A11);
    let mut sim = PipelineSim::new(CpuConfig::default());
    let events = {
        let mut adapter = PerEvent(&mut sim);
        let mut rec = Recorder::new(&mut adapter, 3);
        let _ = w.run(&ds, &ctx(), &mut rec);
        rec.finish();
        rec.events_emitted()
    };
    (sim.metrics(), events)
}

#[test]
fn block_pipeline_matches_legacy_event_counts_and_metrics() {
    // one workload per paper category, plus the branch-heavy tree case
    for name in ["KMeans", "KNN", "Ridge", "Decision Tree"] {
        let w = common::workload(name);
        let (block_m, block_events) = run_block_path(w.as_ref(), 500);
        let (legacy_m, legacy_events) = run_legacy_path(w.as_ref(), 500);
        assert_eq!(block_events, legacy_events, "{name}: event counts diverge");
        assert!(block_events > 1_000, "{name}: trivial trace ({block_events} events)");
        assert_eq!(block_m, legacy_m, "{name}: metrics diverge");
    }
}

#[test]
fn parity_holds_with_software_prefetching() {
    let w = common::workload("KNN");
    let ds = w.make_dataset(400, 8, 0x9A12);

    let run = |legacy: bool| -> (Metrics, u64) {
        let mut sim = PipelineSim::new(CpuConfig::default());
        let events = if legacy {
            let mut adapter = PerEvent(&mut sim);
            let mut rec = Recorder::new(&mut adapter, 3);
            rec.sw_prefetch_enabled = true;
            let _ = w.run(&ds, &ctx(), &mut rec);
            rec.finish();
            rec.events_emitted()
        } else {
            let mut rec = Recorder::new(&mut sim, 3);
            rec.sw_prefetch_enabled = true;
            let _ = w.run(&ds, &ctx(), &mut rec);
            rec.finish();
            rec.events_emitted()
        };
        (sim.metrics(), events)
    };

    let (block_m, block_events) = run(false);
    let (legacy_m, legacy_events) = run(true);
    assert_eq!(block_events, legacy_events);
    assert!(block_m.mix.sw_prefetches > 0, "prefetch events expected");
    assert_eq!(block_m, legacy_m);
}

#[test]
fn workload_quality_is_path_independent() {
    // the trace transport must not perturb the algorithm itself
    let w = common::workload("KMeans");
    let ds = w.make_dataset(400, 6, 0x9A13);
    let mut sim_a = PipelineSim::new(CpuConfig::default());
    let mut sim_b = PipelineSim::new(CpuConfig::default());
    let q_block = {
        let mut rec = Recorder::new(&mut sim_a, 3);
        w.run(&ds, &ctx(), &mut rec).quality
    };
    let q_legacy = {
        let mut adapter = PerEvent(&mut sim_b);
        let mut rec = Recorder::new(&mut adapter, 3);
        w.run(&ds, &ctx(), &mut rec).quality
    };
    assert_eq!(q_block, q_legacy);
}
