//! Property-based tests over coordinator/simulator invariants.
//!
//! No external proptest crate is available offline, so this file uses a
//! small self-contained generator loop over the crate's own PCG64: each
//! property is checked across a randomized sweep of configurations, and
//! failures print the offending seed for replay.

use mlperf::data::make_blobs;
use mlperf::reorder::{compute_plan, sfc, ReorderKind};
use mlperf::sim::{AddrMap, CpuConfig, Dram, DramConfig, Hierarchy, HierarchyConfig, PipelineSim};
use mlperf::trace::{Event, Recorder, Sink};
use mlperf::util::binio::{get_ivarint, get_uvarint, put_ivarint, put_uvarint, ByteCursor};
use mlperf::util::Pcg64;
use mlperf::workloads::{by_name, RunContext};

/// Run `body` over `n` random cases derived from a base seed.
fn sweep(name: &str, n: u64, mut body: impl FnMut(&mut Pcg64, u64)) {
    for case in 0..n {
        let seed = 0xBEEF ^ (case * 0x9E37_79B9);
        let mut rng = Pcg64::new(seed);
        // bubble panics with the seed attached
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng, seed)
        }));
        if let Err(e) = result {
            panic!("property {name} failed for seed {seed:#x}: {e:?}");
        }
    }
}

/// Cache invariant: a line is always a hit immediately after any access
/// that loaded it, regardless of the surrounding access pattern.
#[test]
fn prop_cache_hit_after_access() {
    sweep("hit-after-access", 20, |rng, _| {
        let cfg = HierarchyConfig {
            l1_bytes: 4096,
            l1_ways: 2,
            l2_bytes: 16384,
            l2_ways: 4,
            l3_bytes: 65536,
            l3_ways: 4,
            hw_prefetch: rng.next_f64() < 0.5,
            perfect_l2: false,
            perfect_llc: false,
        };
        let mut h = Hierarchy::new(&cfg);
        let mut dram = Vec::new();
        for _ in 0..2000 {
            let addr = rng.below(1 << 22) & !7;
            h.access(addr, 8, rng.next_f64() < 0.3, &mut dram);
            let (lvl, _) = h.access(addr, 8, false, &mut dram);
            assert_eq!(lvl, mlperf::sim::Level::L1, "addr {addr:#x}");
            dram.clear();
        }
    });
}

/// Cache invariant: miss counts are monotone in the access stream and
/// never exceed accesses.
#[test]
fn prop_cache_stats_sane() {
    sweep("cache-stats", 10, |rng, _| {
        let mut h = Hierarchy::new(&HierarchyConfig::default());
        let mut dram = Vec::new();
        for _ in 0..20_000 {
            let addr = rng.below(1 << 28);
            h.access(addr, 1 + (rng.below(256)) as u32, false, &mut dram);
            dram.clear();
        }
        for c in [&h.l1, &h.l2, &h.l3] {
            assert!(c.stats.misses <= c.stats.accesses);
        }
        // inclusive-ish ordering: L2 sees at most L1's misses (demand)
        assert!(h.l2.stats.accesses <= h.l1.stats.misses);
        assert!(h.l3.stats.accesses <= h.l2.stats.misses);
    });
}

/// DRAM invariant: hits + misses + conflicts == requests; ideal mode is
/// never slower than the real mode on the same stream.
#[test]
fn prop_dram_accounting_and_ideal_bound() {
    sweep("dram-accounting", 10, |rng, _| {
        let mut real = Dram::new(DramConfig::default());
        let mut ideal = Dram::new(DramConfig { ideal_row_hits: true, ..Default::default() });
        let mut t = 0.0;
        for _ in 0..5_000 {
            let addr = rng.below(1 << 32) & !63;
            real.request(t, addr, false, false);
            ideal.request(t, addr, false, false);
            t += rng.uniform(3.0, 200.0);
        }
        let s = &real.stats;
        assert_eq!(s.row_hits + s.row_misses + s.row_conflicts, s.requests);
        assert!(ideal.stats.avg_latency_ns() <= real.stats.avg_latency_ns() + 1e-9);
    });
}

/// DRAM invariant: both address mappings are total and bank/row bounded.
#[test]
fn prop_addr_maps_in_range() {
    sweep("addr-map", 6, |rng, _| {
        for map in [AddrMap::RoBaRaCoCh, AddrMap::ChRaBaRoCo] {
            let d = Dram::new(DramConfig { addr_map: map, ..Default::default() });
            for _ in 0..5_000 {
                let c = d.map(rng.below(1 << 35));
                assert!(c.bank < 16 && c.row < 32 * 1024);
            }
        }
    });
}

/// SFC invariant: every curve order is a permutation, for random shapes.
#[test]
fn prop_sfc_orders_are_permutations() {
    sweep("sfc-perm", 8, |rng, seed| {
        let n = 16 + rng.index(200);
        let m = 1 + rng.index(8);
        let ds = make_blobs(n, m, 1 + rng.index(4), 0.5 + rng.next_f64(), seed);
        let bits = sfc::max_bits_for_dims(m);
        for hilbert in [false, true] {
            let mut ord = sfc::sfc_order(&ds.x, bits, hilbert);
            ord.sort_unstable();
            assert_eq!(ord, (0..n).collect::<Vec<_>>());
        }
    });
}

/// Reordering invariant: for every kind and random small datasets, the
/// plan is a permutation and `apply` preserves the (row, label) pairing.
#[test]
fn prop_reorder_plans_preserve_data() {
    sweep("reorder-preserve", 6, |rng, seed| {
        let w = by_name("kmeans").unwrap();
        let n = 64 + rng.index(200);
        let ds = make_blobs(n, 4, 3, 1.0, seed);
        let ctx = RunContext::default();
        for kind in ReorderKind::ALL {
            let mut sink = mlperf::trace::NullSink;
            let mut rec = Recorder::new(&mut sink, 40);
            let plan = compute_plan(kind, &ds, w.as_ref(), &ctx, &mut rec);
            let mut p = plan.perm.clone();
            p.sort_unstable();
            assert_eq!(p, (0..n).collect::<Vec<_>>(), "{kind}");
            let (ds2, _) = plan.apply(&ds, &ctx);
            if kind.is_data_layout() {
                for i in 0..n {
                    assert_eq!(ds2.x.row(i), ds.x.row(plan.perm[i]));
                    assert_eq!(ds2.y[i], ds.y[plan.perm[i]]);
                }
            } else {
                assert_eq!(ds2.x, ds.x);
            }
        }
    });
}

/// Pipeline invariant: metrics are finite, top-down sums ≤ ~100%, port
/// distribution sums to 1 — under arbitrary random event streams.
#[test]
fn prop_pipeline_metrics_bounded() {
    sweep("pipeline-bounded", 10, |rng, _| {
        let mut sim = PipelineSim::new(CpuConfig::default());
        for _ in 0..5_000 {
            let ev = match rng.below(6) {
                0 => Event::Compute {
                    int_ops: rng.below(8) as u32,
                    fp_ops: rng.below(8) as u32,
                },
                1 => Event::Serial { ops: 1 + rng.below(4) as u32 },
                2 => Event::Load {
                    addr: rng.below(1 << 30),
                    size: 1 + rng.below(512) as u32,
                    feeds_branch: rng.next_f64() < 0.2,
                },
                3 => Event::Store { addr: rng.below(1 << 30), size: 8 },
                4 => Event::Branch {
                    site: rng.below(64) as u32,
                    taken: rng.next_f64() < 0.5,
                    conditional: rng.next_f64() < 0.9,
                },
                _ => Event::SwPrefetch { addr: rng.below(1 << 30) },
            };
            sim.event(ev);
        }
        Sink::finish(&mut sim);
        let m = sim.metrics();
        assert!(m.cycles.is_finite() && m.cycles > 0.0);
        assert!(m.cpi.is_finite());
        let sum = m.retiring_pct + m.bad_spec_pct + m.core_bound_pct + m.mem_bound_pct;
        assert!((0.0..=105.0).contains(&sum), "top-down sum {sum}");
        let pd: f64 = m.port_dist.iter().sum();
        assert!((pd - 1.0).abs() < 1e-6);
        assert!(m.port_dist.iter().all(|&p| (-1e-9..=1.0 + 1e-9).contains(&p)));
    });
}

/// Workload invariant: traces are deterministic per seed across repeated
/// runs (the whole experiment pipeline depends on this).
#[test]
fn prop_workload_traces_deterministic() {
    sweep("trace-deterministic", 3, |rng, seed| {
        let names = ["kmeans", "knn", "ridge"];
        let name = names[rng.index(names.len())];
        let w = by_name(name).unwrap();
        let ds = w.make_dataset(400, 5, seed);
        let ctx = RunContext { iterations: 1, ..Default::default() };
        let run = || {
            let mut mix = mlperf::trace::InstructionMix::default();
            {
                let mut rec = Recorder::new(&mut mix, 9);
                w.run(&ds, &ctx, &mut rec);
            }
            mix
        };
        assert_eq!(run(), run(), "{name} trace must be deterministic");
    });
}

/// Codec invariant: the `ByteCursor` unrolled varint fast path agrees
/// with the reference `get_uvarint`/`get_ivarint` decoders on *every*
/// input — encoded values across the full width spectrum, random byte
/// soup, and adversarial cases (max-width, overlong, truncated). Both
/// must produce the same value and end position, or both must error.
#[test]
fn prop_varint_fast_path_matches_reference() {
    // 1. round-trips of random values, biased toward the 1–2-byte range
    //    the fast path covers
    sweep("varint-roundtrip", 8, |rng, _| {
        let mut buf = Vec::new();
        let mut vals = Vec::new();
        for _ in 0..500 {
            // pick an encoded width first so 1–2-byte values (the fast
            // path) and 9–10-byte values (the slow path) both get dense
            // coverage
            let bits = 7 * (1 + rng.index(10) as u32);
            let v = rng.below(u64::MAX >> (64 - bits.min(64)));
            vals.push(v);
            put_uvarint(&mut buf, v);
            let s = v as i64;
            vals.push(s as u64);
            put_ivarint(&mut buf, s);
        }
        let mut cur = ByteCursor::new(&buf);
        let mut pos = 0usize;
        for (i, &v) in vals.iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(cur.uvarint().unwrap(), v);
                assert_eq!(get_uvarint(&buf, &mut pos).unwrap(), v);
            } else {
                assert_eq!(cur.ivarint().unwrap(), v as i64);
                assert_eq!(get_ivarint(&buf, &mut pos).unwrap(), v as i64);
            }
            assert_eq!(cur.pos(), pos, "positions diverged at value {i}");
        }
        assert!(cur.is_empty());
    });

    // 2. random byte soup: at every start offset, fast path and
    //    reference must agree on (value, end) or both reject
    sweep("varint-soup", 8, |rng, seed| {
        let bytes: Vec<u8> = (0..200).map(|_| rng.below(256) as u8).collect();
        for start in 0..bytes.len() {
            let mut cur = ByteCursor::new(&bytes[start..]);
            let mut pos = 0usize;
            let fast = cur.uvarint();
            let reference = get_uvarint(&bytes[start..], &mut pos);
            match (fast, reference) {
                (Ok(a), Ok(b)) => {
                    assert_eq!(a, b, "seed {seed:#x} offset {start}");
                    assert_eq!(cur.pos(), pos, "seed {seed:#x} offset {start}");
                }
                (Err(_), Err(_)) => {}
                (f, r) => panic!(
                    "seed {seed:#x} offset {start}: fast {f:?} vs reference {r:?}"
                ),
            }
        }
    });

    // 3. adversarial fixtures: max-width, overlong, truncated
    let fixtures: &[&[u8]] = &[
        &[],
        &[0x80],
        &[0x80, 0x80],
        &[0xFF; 9],
        b"\xFF\xFF\xFF\xFF\xFF\xFF\xFF\xFF\xFF\x01", // u64::MAX
        b"\x80\x80\x80\x80\x80\x80\x80\x80\x80\x7E", // 10th byte too wide
        b"\x80\x80\x80\x80\x80\x80\x80\x80\x80\x80\x01", // 11 bytes
        &[0x00],
        &[0x7F],
        &[0x80, 0x01],
        &[0x80, 0x80, 0x01],
    ];
    for &fx in fixtures {
        let mut cur = ByteCursor::new(fx);
        let mut pos = 0usize;
        let fast = cur.uvarint();
        let reference = get_uvarint(fx, &mut pos);
        match (&fast, &reference) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a, b, "{fx:?}");
                assert_eq!(cur.pos(), pos, "{fx:?}");
            }
            (Err(_), Err(_)) => {}
            _ => panic!("{fx:?}: fast {fast:?} vs reference {reference:?}"),
        }
    }
}
