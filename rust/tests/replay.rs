//! Record-once/replay-many integration tests: a trace recorded from a
//! real workload and replayed through `PipelineSim` — from memory or
//! from disk — must produce `Metrics` bit-identical to direct execution,
//! for both library profiles, with and without software prefetching, and
//! under scenario CPU-config mutations. Corruption must surface as clean
//! errors, and the replay grid driver must execute each workload exactly
//! once however many scenario cells it serves.

use mlperf::coordinator::{
    characterize, characterize_with, record_characterize, replay_characterize, replay_file,
    run_jobs, run_jobs_replayed, ExperimentConfig, Job, Scenario,
};
use mlperf::workloads::LibraryProfile;

mod common;

fn tiny(profile: LibraryProfile) -> ExperimentConfig {
    common::tiny_profile(profile)
}

fn tmpfile(name: &str) -> std::path::PathBuf {
    common::tmpfile("replay", name)
}

#[test]
fn file_replay_matches_direct_execution_across_workloads_and_profiles() {
    for profile in [LibraryProfile::Sklearn, LibraryProfile::Mlpack] {
        for name in ["KMeans", "KNN", "Decision Tree"] {
            let cfg = tiny(profile);
            let w = common::workload(name);
            let direct = characterize(w.as_ref(), &cfg);
            let path = tmpfile(&format!("{}_{profile:?}.mlt", name.replace(' ', "_")));
            let (recorded, summary) =
                record_characterize(w.as_ref(), &cfg, false, &path).unwrap();
            assert_eq!(
                recorded.metrics, direct.metrics,
                "{name}/{profile:?}: the recording run's own simulation diverged"
            );
            assert_eq!(recorded.result.quality, direct.result.quality);
            assert!(summary.events > 1_000, "{name}/{profile:?}: trivial trace");
            let (meta, replayed, stats) = replay_file(&path, &cfg, |_| {}).unwrap();
            assert_eq!(meta.workload, name);
            assert_eq!(meta.profile, profile);
            assert_eq!(stats.events, summary.events);
            assert_eq!(stats.blocks, summary.blocks);
            assert_eq!(replayed, direct.metrics, "{name}/{profile:?}: file replay diverged");
        }
    }
}

#[test]
fn file_replay_honours_prefetch_variant_and_scenario_mutations() {
    let cfg = tiny(LibraryProfile::Sklearn);
    let w = common::workload("KNN");

    // prefetch-enabled recording is its own trace variant
    let pf_path = tmpfile("knn_pf.mlt");
    record_characterize(w.as_ref(), &cfg, true, &pf_path).unwrap();
    let direct_pf = characterize_with(w.as_ref(), &cfg, true, None, None, |_| {});
    let (meta, replayed_pf, _) = replay_file(&pf_path, &cfg, |_| {}).unwrap();
    assert!(meta.sw_prefetch);
    assert!(replayed_pf.mix.sw_prefetches > 0, "prefetch events must survive the store");
    assert_eq!(replayed_pf, direct_pf.metrics);

    // CPU-config scenario applied at replay time, not record time
    let base_path = tmpfile("knn_base.mlt");
    record_characterize(w.as_ref(), &cfg, false, &base_path).unwrap();
    let direct_l2 =
        characterize_with(w.as_ref(), &cfg, false, None, None, |c| c.cache.perfect_l2 = true);
    let (_, replayed_l2, _) =
        replay_file(&base_path, &cfg, |c| c.cache.perfect_l2 = true).unwrap();
    assert_eq!(replayed_l2, direct_l2.metrics);
}

#[test]
fn in_memory_capture_written_to_disk_replays_identically() {
    let cfg = tiny(LibraryProfile::Sklearn);
    let recorded = common::capture("GMM", &cfg, false);
    let from_memory = replay_characterize(&recorded, &cfg, |_| {});

    let path = tmpfile("gmm_mem.mlt");
    let summary = recorded.trace.write_to(&path, &recorded.meta).unwrap();
    assert_eq!(summary.events, recorded.trace.events());
    let (meta, from_disk, stats) = replay_file(&path, &cfg, |_| {}).unwrap();
    assert_eq!(meta, recorded.meta);
    assert_eq!(stats.events, summary.events);
    assert_eq!(from_disk, from_memory, "disk and memory replays must agree bit-for-bit");
}

#[test]
fn four_scenario_grid_replays_from_one_execution() {
    let cfg = tiny(LibraryProfile::Sklearn);
    let scenarios = [
        Scenario::Baseline,
        Scenario::PerfectL2,
        Scenario::PerfectLlc,
        Scenario::DramIdealRows,
    ];
    let jobs: Vec<Job> = scenarios.iter().map(|s| Job::new("DBSCAN", *s)).collect();
    let direct = run_jobs(&cfg, &jobs, 2);
    let replayed = run_jobs_replayed(&cfg, &jobs, 2);
    assert_eq!(replayed.workload_executions, 1, "one capture must serve all 4 cells");
    assert_eq!(direct.workload_executions, jobs.len());
    assert_eq!(replayed.outputs.len(), jobs.len());
    for (a, b) in direct.outputs.iter().zip(&replayed.outputs) {
        assert_eq!(a.job, b.job);
        assert_eq!(a.metrics, b.metrics, "replay grid diverged for {:?}", a.job);
        assert_eq!(a.quality, b.quality);
    }
}

#[test]
fn replay_file_reports_corruption_cleanly() {
    let cfg = tiny(LibraryProfile::Sklearn);
    let w = common::workload("Ridge");
    let path = tmpfile("ridge_corrupt.mlt");
    record_characterize(w.as_ref(), &cfg, false, &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    let err = replay_file(&path, &cfg, |_| {}).unwrap_err().to_string();
    assert!(
        ["checksum", "truncated", "cap", "marker", "trailer", "decoding"]
            .iter()
            .any(|needle| err.contains(needle)),
        "corruption produced an unhelpful error: {err}"
    );
}
