//! Sampled-simulation statistical gates (`--sample`, [`SampledSim`]).
//!
//! Three contracts:
//!
//! 1. **Estimate-within-CI** — for every workload × profile × replayable
//!    scenario column, the sampled CPI estimate must cover the full-run
//!    ground truth inside its own reported 95% interval, and every
//!    state-derived metric (miss ratios, branch stats, prefetch stats,
//!    instruction mix) must equal the full run *bit-exactly*, because
//!    functional warming evolves that state identically.
//! 2. **Coverage** — over many seeds of a synthetic stream, the nominal
//!    95% interval must contain the truth at (at least) the expected
//!    rate.
//! 3. **Degenerate escape hatch** — `detail == period` must reproduce
//!    the full-run `Metrics` bit-exactly with a zero-width interval.

use mlperf::coordinator::{
    replay_characterize, replay_characterize_many, replay_characterize_many_sampled,
    replay_characterize_sampled, Scenario,
};
use mlperf::sim::{CpuConfig, Metrics, PipelineSim, SampleConfig, SampleReport, SampledSim};
use mlperf::trace::{BlockSink, Event, EventBlock};
use mlperf::util::Pcg64;
use mlperf::workloads::{supported_names, LibraryProfile};

mod common;

/// Dense enough windows for tiny integration traces: 2-block detailed
/// windows every 16 blocks (12.5% detail) gives several windows even at
/// scale 0.02 while still exercising the warm path hard.
const SAMPLE: SampleConfig = SampleConfig { detail: 2, period: 16 };

/// Everything functional warming promises to keep exact, in one place.
fn assert_state_metrics_exact(est: &Metrics, full: &Metrics, what: &str) {
    assert_eq!(est.instructions, full.instructions, "{what}: instructions");
    assert_eq!(est.mix, full.mix, "{what}: instruction mix");
    assert_eq!(est.branch, full.branch, "{what}: branch stats");
    assert_eq!(est.prefetch, full.prefetch, "{what}: prefetch stats");
    assert_eq!(est.l1_miss_ratio, full.l1_miss_ratio, "{what}: L1 miss ratio");
    assert_eq!(est.l2_miss_ratio, full.l2_miss_ratio, "{what}: L2 miss ratio");
    assert_eq!(est.llc_miss_ratio, full.llc_miss_ratio, "{what}: LLC miss ratio");
    assert_eq!(
        est.branch_mispredict_ratio, full.branch_mispredict_ratio,
        "{what}: mispredict ratio"
    );
}

fn assert_within_ci(rep: &SampleReport, full: &Metrics, what: &str) {
    assert!(rep.cpi_ci95 > 0.0, "{what}: sampled run must report a nonzero interval");
    assert!(
        rep.cpi_within_ci(full.cpi),
        "{what}: estimate {} ± {} does not cover truth {}",
        rep.estimate.cpi,
        rep.cpi_ci95,
        full.cpi
    );
}

/// Contract 1: every workload the profile implements, every replayable
/// scenario column, one shared capture per workload — full-run truth vs
/// sampled estimate.
#[test]
fn estimate_covers_truth_for_every_workload_profile_and_scenario() {
    let scenarios = [
        Scenario::Baseline,
        Scenario::PerfectL2,
        Scenario::PerfectLlc,
        Scenario::NoHwPrefetch,
        Scenario::DramIdealRows,
    ];
    for profile in [LibraryProfile::Sklearn, LibraryProfile::Mlpack] {
        let cfg = common::tiny_profile(profile);
        for name in supported_names(profile) {
            let rec = common::capture(name, &cfg, false);
            let fulls = replay_characterize_many(&rec, &cfg, &scenarios);
            let reps = replay_characterize_many_sampled(&rec, &cfg, &scenarios, SAMPLE);
            assert_eq!(fulls.len(), reps.len());
            for ((s, full), rep) in scenarios.iter().zip(&fulls).zip(&reps) {
                let what = format!("{name}/{profile:?}/{s}");
                assert!(!rep.degenerate, "{what}");
                // traces shorter than one period legitimately run fully
                // detailed; past that, sampling must actually skip blocks
                if rep.blocks_total > SAMPLE.period {
                    assert!(
                        rep.blocks_detailed < rep.blocks_total,
                        "{what}: sampling must skip blocks ({} of {} detailed)",
                        rep.blocks_detailed,
                        rep.blocks_total
                    );
                }
                assert_state_metrics_exact(&rep.estimate, full, &what);
                assert_within_ci(rep, full, &what);
            }
        }
    }
}

/// The software-prefetch column rides its own trace variant; the sampled
/// contract must hold there too (prefetch lanes go through the warm
/// path's tag walk like any other memory event).
#[test]
fn estimate_covers_truth_on_the_prefetch_trace_variant() {
    let cfg = common::tiny();
    let rec = common::capture("KNN", &cfg, true);
    let full = replay_characterize(&rec, &cfg, |_| {});
    assert!(full.mix.sw_prefetches > 0, "prefetch variant must carry prefetch events");
    let rep = replay_characterize_sampled(&rec, &cfg, SAMPLE, |_| {});
    assert_state_metrics_exact(&rep.estimate, &full, "KNN/sw-prefetch");
    assert_within_ci(&rep, &full, "KNN/sw-prefetch");
}

/// Contract 3: `detail == period` (and any detail >= period) is a pure
/// pass-through — the whole Metrics struct equals an unsampled replay,
/// bit for bit, on a real workload trace.
#[test]
fn degenerate_period_equals_detail_is_bit_exact_on_real_traces() {
    let cfg = common::tiny();
    for name in ["KMeans", "Decision Tree"] {
        let rec = common::capture(name, &cfg, false);
        let full = replay_characterize(&rec, &cfg, |_| {});
        for sc in [SampleConfig { detail: 4, period: 4 }, SampleConfig { detail: 9, period: 3 }] {
            let rep = replay_characterize_sampled(&rec, &cfg, sc, |_| {});
            assert!(rep.degenerate, "{name} {sc}");
            assert_eq!(rep.cpi_ci95, 0.0, "{name} {sc}: degenerate interval must be zero");
            assert_eq!(rep.estimate, full, "{name} {sc}: degenerate sampling drifted");
            assert_eq!(rep.blocks_detailed, rep.blocks_total);
        }
    }
}

/// Synthetic stream with deliberate phase structure (block-scale
/// behaviour changes) so the inter-window variance is real, not zero.
fn phased_blocks(n_events: usize, seed: u64) -> Vec<EventBlock> {
    let mut rng = Pcg64::new(seed);
    let mut blocks = Vec::new();
    let mut block = EventBlock::with_capacity();
    for i in 0..n_events {
        // alternate between a compute-heavy and a memory-heavy phase
        // every ~3 blocks worth of events
        let memory_phase = (i / 12_288) % 2 == 1;
        let roll = rng.below(if memory_phase { 5 } else { 8 });
        let ev = match roll {
            0 | 1 => Event::Load {
                addr: rng.below(1 << 26),
                size: 1 + rng.below(64) as u32,
                feeds_branch: rng.next_f64() < 0.15,
            },
            2 => Event::Store { addr: rng.below(1 << 26), size: 8 },
            3 => Event::Branch {
                site: rng.below(64) as u32,
                taken: rng.next_f64() < 0.5,
                conditional: true,
            },
            _ => Event::Compute {
                int_ops: 1 + rng.below(4) as u32,
                fp_ops: rng.below(4) as u32,
            },
        };
        block.push_event(ev);
        if block.is_full() {
            blocks.push(std::mem::replace(&mut block, EventBlock::with_capacity()));
        }
    }
    if !block.is_empty() {
        blocks.push(block);
    }
    blocks
}

fn run_full(blocks: &[EventBlock]) -> Metrics {
    let mut sim = PipelineSim::new(CpuConfig::default());
    for b in blocks {
        sim.consume(b);
    }
    BlockSink::finalize(&mut sim);
    sim.metrics()
}

fn run_sampled(blocks: &[EventBlock], sc: SampleConfig) -> SampleReport {
    let mut s = SampledSim::new(PipelineSim::new(CpuConfig::default()), sc);
    for b in blocks {
        s.consume(b);
    }
    BlockSink::finalize(&mut s);
    s.into_report()
}

/// Contract 2: coverage of the nominal 95% interval over many seeds.
/// The CI carries a relative floor for windowing bias, so empirical
/// coverage should sit at or above nominal; gate at 90% to leave slack
/// for the finite number of trials, and require that misses — if any —
/// miss by little.
#[test]
fn nominal_95_interval_covers_truth_at_expected_rate() {
    const TRIALS: u64 = 30;
    let mut covered = 0usize;
    let mut worst_excess = 0.0f64;
    for seed in 0..TRIALS {
        let blocks = phased_blocks(120_000, 1000 + seed);
        let full = run_full(&blocks);
        let rep = run_sampled(&blocks, SAMPLE);
        assert!(rep.windows >= 2, "seed {seed}: want >= 2 windows, got {}", rep.windows);
        assert_state_metrics_exact(&rep.estimate, &full, &format!("seed {seed}"));
        if rep.cpi_within_ci(full.cpi) {
            covered += 1;
        } else {
            let excess = (full.cpi - rep.estimate.cpi).abs() / rep.cpi_ci95.max(1e-12);
            worst_excess = worst_excess.max(excess);
        }
    }
    let rate = covered as f64 / TRIALS as f64;
    assert!(
        rate >= 0.9,
        "95% interval covered truth in only {covered}/{TRIALS} trials ({rate:.2})"
    );
    if covered < TRIALS as usize {
        assert!(
            worst_excess < 2.0,
            "an uncovered trial missed by {worst_excess:.2}x the interval — estimator bias, \
             not sampling noise"
        );
    }
}

/// Sampling must be invariant to how blocks are delivered: the same
/// schedule lands on the same blocks whether the stream comes from a
/// trace replay or is pushed block by block (positional scheduling).
#[test]
fn sampled_estimates_are_deterministic_across_runs() {
    let cfg = common::tiny();
    let rec = common::capture("GMM", &cfg, false);
    let a = replay_characterize_sampled(&rec, &cfg, SAMPLE, |_| {});
    let b = replay_characterize_sampled(&rec, &cfg, SAMPLE, |_| {});
    assert_eq!(a.estimate, b.estimate, "sampled replay is not deterministic");
    assert_eq!(a.cpi_ci95, b.cpi_ci95);
    assert_eq!(a.windows, b.windows);
}
