//! Serving-layer integration tests: the `mlperf serve` daemon answering
//! grid queries from its sharded ledger. The contracts under test:
//!
//! - answers are **bit-identical** to a direct `run_jobs_replayed` grid,
//!   cold and warm, and a drained daemon exits cleanly releasing its
//!   lock files;
//! - N concurrent misses on one fingerprint **coalesce** into exactly
//!   one simulation;
//! - rejections are **typed and deterministic** (`deadline-exceeded`,
//!   `overloaded`), and serve-path chaos (`conn-drop`, `slow-client`)
//!   degrades single connections without harming the daemon;
//! - a `serve-kill` hard crash mid-soak loses nothing that was already
//!   answered: a warm restart serves every prior query from the shards
//!   with zero re-simulation and byte-identical metrics;
//! - the pidfile refuses double-starts and is released on drain.
//!
//! The fault plan is process-global and the in-process daemons consult
//! it, so every test serializes through [`serve_lock`].

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use mlperf::coordinator::{run_jobs_replayed, Job, Scenario};
use mlperf::ledger::TRACKED;
use mlperf::serve::{discover_addr, Client, ServeOptions, Server, ADDRFILE, PIDFILE};
use mlperf::util::fault::{self, FaultPlan};
use mlperf::util::json::Json;

mod common;

/// Serialize the suite: daemons poll the process-global fault plan, and
/// several tests install one.
fn serve_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arms a chaos spec for one scope and disarms on drop (panic-safe).
struct Armed;

impl Armed {
    fn new(spec: &str) -> Armed {
        fault::install(Some(FaultPlan::parse(spec).expect("chaos spec must parse")));
        Armed
    }
}

impl Drop for Armed {
    fn drop(&mut self) {
        fault::install(None);
    }
}

/// A fresh serve directory under the per-suite temp root.
fn serve_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mlperf-serve-tests-{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Bind and run an in-process daemon over the [`common::tiny`] config.
fn start(
    dir: &Path,
    queue_depth: usize,
) -> (String, std::thread::JoinHandle<mlperf::util::error::Result<()>>) {
    let opts = ServeOptions {
        dir: dir.to_path_buf(),
        queue_depth,
        default_deadline_ms: 120_000,
        sim_threads: 1,
        cfg: common::tiny(),
        ..ServeOptions::default()
    };
    let server = Server::bind(opts).expect("bind serve daemon");
    let addr = server.addr().to_string();
    (addr, std::thread::spawn(move || server.run()))
}

/// Drain via the protocol `shutdown` op and join the daemon thread.
fn stop(addr: &str, daemon: std::thread::JoinHandle<mlperf::util::error::Result<()>>) {
    let mut client = Client::connect(addr).expect("connect for shutdown");
    let resp = client.op("shutdown").expect("shutdown request");
    assert_eq!(resp.get("draining").and_then(Json::as_bool), Some(true));
    daemon.join().expect("daemon thread").expect("drain must exit cleanly");
}

fn is_ok(resp: &Json) -> bool {
    resp.get("ok").and_then(Json::as_bool) == Some(true)
}

fn cached(resp: &Json) -> Option<bool> {
    resp.get("cached").and_then(Json::as_bool)
}

fn kind(resp: &Json) -> Option<&str> {
    resp.get("kind").and_then(Json::as_str)
}

fn stat(stats: &Json, field: &str) -> u64 {
    stats
        .get(field)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("stats response missing {field:?}: {}", stats.render()))
        as u64
}

#[test]
fn queries_match_direct_grid_bit_for_bit_and_drain_cleanly() {
    let _lock = serve_lock();
    let cfg = common::tiny();
    let jobs =
        vec![Job::new("KMeans", Scenario::Baseline), Job::new("KMeans", Scenario::PerfectL2)];
    let direct = run_jobs_replayed(&cfg, &jobs, 1);
    assert!(direct.failed.is_empty());

    let dir = serve_dir("parity");
    let (addr, daemon) = start(&dir, 8);
    let mut client = Client::connect(&addr).unwrap();

    for (out, scenario) in [(&direct.outputs[0], "baseline"), (&direct.outputs[1], "perfect-l2")]
    {
        let cold = client.query("KMeans", scenario, None).unwrap();
        assert!(is_ok(&cold), "cold {scenario}: {}", cold.render());
        assert_eq!(cached(&cold), Some(false), "first query must simulate");
        let warm = client.query("KMeans", scenario, None).unwrap();
        assert!(is_ok(&warm));
        assert_eq!(cached(&warm), Some(true), "second query must hit the shards");

        // every tracked metric matches the direct grid to the bit, on
        // both the freshly simulated and the shard-served answer
        for (name, get) in TRACKED {
            let reference = get(&out.metrics);
            for (label, resp) in [("cold", &cold), ("warm", &warm)] {
                let got = resp
                    .get("metrics")
                    .and_then(|m| m.get(name))
                    .and_then(Json::as_f64)
                    .unwrap_or_else(|| panic!("{label} response missing metric {name}"));
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "{scenario}/{name} ({label}): {got} != {reference}"
                );
            }
        }
        assert_eq!(cold.get("quality").and_then(Json::as_f64), out.quality);
    }

    // workload names canonicalize before fingerprinting: an alias
    // spelling is the same cell, served warm
    let alias = client.query("k-means", "baseline", None).unwrap();
    assert!(is_ok(&alias));
    assert_eq!(cached(&alias), Some(true), "alias spelling must hit the same fingerprint");

    let stats = client.op("stats").unwrap();
    assert_eq!(stat(&stats, "admitted"), 5);
    assert_eq!(stat(&stats, "misses"), 2);
    assert_eq!(stat(&stats, "hits"), 3);
    assert_eq!(stat(&stats, "shed"), 0);
    assert_eq!(stat(&stats, "unique_cells"), 2);

    stop(&addr, daemon);
    assert!(!dir.join(ADDRFILE).exists(), "drain must remove the discovery file");
    assert!(!dir.join(PIDFILE).exists(), "drain must release the lock");
}

#[test]
fn concurrent_misses_on_one_fingerprint_simulate_once() {
    let _lock = serve_lock();
    let dir = serve_dir("coalesce");
    let (addr, daemon) = start(&dir, 8);

    let clients = 4;
    let barrier = Arc::new(Barrier::new(clients));
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                barrier.wait();
                client.query("KNN", "baseline", Some(120_000)).unwrap()
            })
        })
        .collect();
    let responses: Vec<Json> =
        handles.into_iter().map(|h| h.join().expect("client thread")).collect();

    let first = responses[0].get("metrics").expect("metrics").render();
    for resp in &responses {
        assert!(is_ok(resp), "{}", resp.render());
        assert_eq!(
            resp.get("metrics").expect("metrics").render(),
            first,
            "coalesced answers diverged"
        );
    }

    let mut probe = Client::connect(&addr).unwrap();
    let stats = probe.op("stats").unwrap();
    assert_eq!(
        stat(&stats, "workload_executions"),
        1,
        "{clients} concurrent misses must simulate exactly once"
    );
    assert_eq!(stat(&stats, "misses"), 1, "exactly one query leads the flight");
    assert_eq!(stat(&stats, "unique_cells"), 1);
    assert_eq!(
        stat(&stats, "misses") + stat(&stats, "coalesced") + stat(&stats, "hits"),
        clients as u64,
        "every query is a miss, a coalesced waiter, or a post-append hit"
    );

    stop(&addr, daemon);
}

#[test]
fn rejections_are_typed_and_serve_chaos_degrades_not_dies() {
    let _lock = serve_lock();
    let dir = serve_dir("reject");
    let (addr, daemon) = start(&dir, 1);
    let mut client = Client::connect(&addr).unwrap();

    // warm one cell so the overload phase below is pure admission
    let warm = client.query("KMeans", "baseline", None).unwrap();
    assert!(is_ok(&warm), "{}", warm.render());

    // an already-expired deadline is a deterministic typed rejection —
    // and must not have simulated anything
    let dl = client.query("DBSCAN", "baseline", Some(0)).unwrap();
    assert!(!is_ok(&dl));
    assert_eq!(kind(&dl), Some("deadline-exceeded"), "{}", dl.render());
    let msg = dl.get("error").and_then(Json::as_str).unwrap_or_default();
    assert!(msg.contains("deadline"), "{msg}");

    // conn-drop: the daemon hangs up on one connection unanswered; the
    // client gets a typed error and the daemon keeps serving others
    {
        let _armed = Armed::new("conn-drop@1");
        let mut doomed = Client::connect(&addr).unwrap();
        let err = doomed.query("KMeans", "baseline", None).unwrap_err().to_string();
        assert!(err.contains("without answering"), "{err}");
    }
    let after = client.query("KMeans", "baseline", None).unwrap();
    assert!(is_ok(&after), "daemon must survive the dropped connection");
    assert_eq!(cached(&after), Some(true));

    // slow-client parks the only admission slot for 1.5s; once stats
    // confirms the slot is held, the next query is shed with a typed
    // overloaded rejection — while the slot holder still completes
    let _armed = Armed::new("slow-client@1=1500");
    let slow = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            c.query("KMeans", "baseline", None).unwrap()
        })
    };
    let t0 = Instant::now();
    loop {
        let stats = client.op("stats").unwrap();
        if stat(&stats, "queue_depth") == 1 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(10), "slow query never took the slot");
        std::thread::sleep(Duration::from_millis(10));
    }
    let shed = client.query("KNN", "baseline", Some(120_000)).unwrap();
    assert!(!is_ok(&shed));
    assert_eq!(kind(&shed), Some("overloaded"), "{}", shed.render());
    let msg = shed.get("error").and_then(Json::as_str).unwrap_or_default();
    assert!(msg.contains("admission queue full"), "{msg}");
    let slow_resp = slow.join().expect("slow client thread");
    assert!(is_ok(&slow_resp), "the admitted slow query must still complete");
    assert_eq!(cached(&slow_resp), Some(true));

    let stats = client.op("stats").unwrap();
    assert!(stat(&stats, "shed") >= 1);
    assert!(stat(&stats, "deadline_misses") >= 1);

    stop(&addr, daemon);
}

#[test]
fn double_start_is_refused_and_the_lock_releases_on_drain() {
    let _lock = serve_lock();
    let dir = serve_dir("dstart");
    let (addr, daemon) = start(&dir, 2);

    let opts = ServeOptions { dir: dir.clone(), cfg: common::tiny(), ..ServeOptions::default() };
    let err = Server::bind(opts).unwrap_err().to_string();
    assert!(err.contains("already running"), "{err}");

    stop(&addr, daemon);
    assert!(!dir.join(PIDFILE).exists());

    // the drain released the lock: a fresh daemon binds the same dir
    let (addr2, daemon2) = start(&dir, 2);
    stop(&addr2, daemon2);
}

fn bin() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_mlperf"));
    // the spawned daemon must only see the chaos spec the test passes
    c.env_remove("MLPERF_CHAOS");
    c.env_remove("MLPERF_TELEMETRY");
    c
}

fn spawn_daemon(dir: &Path, chaos: Option<&str>) -> Child {
    let mut c = bin();
    c.args(["serve", "--listen", "127.0.0.1:0", "--dir"]).arg(dir);
    c.args(["--scale", "0.02", "--iterations", "1", "--threads", "1"]);
    c.args(["--queue-depth", "8", "--default-deadline", "120000"]);
    if let Some(spec) = chaos {
        c.args(["--chaos", spec]);
    }
    c.stdout(Stdio::null()).stderr(Stdio::null());
    c.spawn().expect("spawn serve daemon")
}

/// Poll the `serve.addr` discovery file until the daemon is reachable,
/// failing fast if the child dies first.
fn wait_addr(dir: &Path, child: &mut Child) -> String {
    let t0 = Instant::now();
    loop {
        if let Ok(addr) = discover_addr(dir) {
            if Client::connect(&addr).is_ok() {
                return addr;
            }
        }
        if let Some(status) = child.try_wait().expect("poll daemon") {
            panic!("serve daemon died before serving: {status}");
        }
        assert!(t0.elapsed() < Duration::from_secs(60), "daemon never became reachable");
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn serve_kill_mid_soak_then_restart_answers_everything_from_shards() {
    let _lock = serve_lock();
    let dir = serve_dir("soak");
    let mut child = spawn_daemon(&dir, Some("serve-kill@6"));
    let addr = wait_addr(&dir, &mut child);

    // two client threads, mixed hits and misses; the 6th answered query
    // aborts the daemon mid-soak (after its response is flushed)
    let plans: Vec<Vec<(&str, &str)>> = vec![
        vec![
            ("KMeans", "baseline"),
            ("KMeans", "baseline"),
            ("KMeans", "perfect-l2"),
            ("KMeans", "perfect-llc"),
        ],
        vec![
            ("KNN", "baseline"),
            ("KNN", "baseline"),
            ("KNN", "sw-prefetch"),
            ("DBSCAN", "baseline"),
        ],
    ];
    let handles: Vec<_> = plans
        .into_iter()
        .map(|plan| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut served = Vec::new();
                let Ok(mut client) = Client::connect(&addr) else { return served };
                for (w, s) in plan {
                    match client.query(w, s, Some(120_000)) {
                        Ok(resp) if is_ok(&resp) => served.push((
                            w.to_string(),
                            s.to_string(),
                            resp.get("metrics").expect("metrics").render(),
                        )),
                        // the kill hit: this connection is gone
                        _ => break,
                    }
                }
                served
            })
        })
        .collect();
    let mut served: Vec<(String, String, String)> =
        handles.into_iter().flat_map(|h| h.join().expect("soak client")).collect();
    let status = child.wait().expect("wait for killed daemon");
    assert!(!status.success(), "serve-kill must hard-kill the daemon");
    assert!(!served.is_empty(), "queries answered before the kill");

    // repeats of one cell must have carried identical bytes; after
    // dedup, any surviving (workload, scenario) collision is divergence
    served.sort();
    served.dedup();
    for pair in served.windows(2) {
        assert!(
            pair[0].0 != pair[1].0 || pair[0].1 != pair[1].1,
            "one cell was answered with two different metric sets: {pair:?}"
        );
    }

    // warm restart over the same shards: the stale discovery file goes,
    // the stale pidfile is taken over (its holder is dead)
    let _ = std::fs::remove_file(dir.join(ADDRFILE));
    assert!(dir.join(PIDFILE).exists(), "a hard kill leaves the lock behind");
    let mut child = spawn_daemon(&dir, None);
    let addr = wait_addr(&dir, &mut child);
    let mut client = Client::connect(&addr).unwrap();
    for (w, s, pre_kill) in &served {
        let resp = client.query(w, s, Some(120_000)).unwrap();
        assert!(is_ok(&resp), "{w}/{s}: {}", resp.render());
        assert_eq!(cached(&resp), Some(true), "{w}/{s} must come from the shards");
        assert_eq!(
            resp.get("metrics").expect("metrics").render(),
            *pre_kill,
            "{w}/{s} drifted across the crash"
        );
    }
    let stats = client.op("stats").unwrap();
    assert_eq!(
        stat(&stats, "workload_executions"),
        0,
        "warm restart must answer every prior query with zero re-simulation"
    );

    let resp = client.op("shutdown").unwrap();
    assert_eq!(resp.get("draining").and_then(Json::as_bool), Some(true));
    let status = child.wait().expect("wait for drained daemon");
    assert!(status.success(), "protocol drain must exit 0");
    assert!(!dir.join(PIDFILE).exists(), "drain must release the lock");
}
