//! Reuse-distance stack profiler parity gate: for every swept geometry,
//! the miss count the single-pass [`StackProfiler`] derives from its
//! per-set-class reuse-distance histograms must equal — bit-exactly —
//! what the packed [`Cache`] reports when the identical demand line
//! stream is driven through it in exact-LRU mode (`demand_probe` + plain
//! `fill`). One trace pass versus one full simulation per geometry,
//! same numbers.

use mlperf::coordinator::ExperimentConfig;
use mlperf::sim::{default_sweep, demand_lines, Cache, StackProfiler, SweepGeometry};
use mlperf::trace::{BlockSink, EventBlock};
use mlperf::util::Pcg64;

mod common;

/// Extracts the demand line stream exactly as the profiler consumes it.
#[derive(Default)]
struct DemandLog {
    lines: Vec<u64>,
}

impl BlockSink for DemandLog {
    fn consume(&mut self, block: &EventBlock) {
        demand_lines(block, &mut self.lines);
    }
    fn finalize(&mut self) {}
}

/// Drive `lines` through a standalone packed cache as exact LRU:
/// demand probes only, plain demand fills on miss.
fn packed_cache_misses(lines: &[u64], g: SweepGeometry) -> (u64, u64) {
    let mut cache = Cache::new(g.bytes, g.ways);
    for &l in lines {
        let (hit, _, _) = cache.demand_probe(l, false);
        if !hit {
            cache.fill(l, false, false, false);
        }
    }
    (cache.stats.accesses, cache.stats.misses)
}

#[test]
fn profiler_matches_packed_cache_on_real_workload_traces() {
    // half the shared tiny scale: this gate simulates one full cache per
    // geometry, so it pays for trace length several times over
    let cfg = ExperimentConfig { scale: 0.01, ..common::tiny() };
    // a spread of the default sweep (both extremes included) keeps the
    // per-geometry cache simulations affordable; the synthetic test
    // below covers every geometry
    let all = default_sweep();
    let mut geometries: Vec<SweepGeometry> = all.iter().copied().step_by(4).collect();
    geometries.push(all[all.len() - 1]);
    for name in ["KMeans", "KNN"] {
        let recorded = common::capture(name, &cfg, false);

        let mut prof = StackProfiler::new(&geometries);
        recorded.trace.replay_into(&mut prof);

        let mut log = DemandLog::default();
        recorded.trace.replay_into(&mut log);
        assert!(!log.lines.is_empty(), "{name}: trivial demand stream");
        assert_eq!(prof.accesses(), log.lines.len() as u64, "{name}: access count");

        for &g in &geometries {
            let (accesses, misses) = packed_cache_misses(&log.lines, g);
            assert_eq!(accesses, prof.accesses(), "{name} @ {g}");
            assert_eq!(
                misses,
                prof.misses_for(g),
                "{name} @ {g}: stack-derived misses != simulated exact-LRU misses"
            );
        }
    }
}

#[test]
fn profiler_matches_packed_cache_on_every_default_geometry() {
    // synthetic stream mixing dense sequential reuse (stack distances
    // around the working-set size), a strided scan, and random far
    // accesses — exercises cold misses, deep reuse, eviction, and the
    // slot-compaction path at every set-class depth
    let mut rng = Pcg64::new(7);
    let mut lines: Vec<u64> = Vec::new();
    for _ in 0..3 {
        for i in 0..20_000u64 {
            lines.push(i % 9_000);
        }
    }
    for i in 0..15_000u64 {
        lines.push(10_000 + i * 17 % 12_000);
    }
    for _ in 0..40_000 {
        lines.push(rng.next_u64() % 30_000);
    }

    // every default geometry plus a direct-mapped and a 3-way oddball
    // (128 sets — legal: sets must be a power of two, ways need not be)
    let mut geometries = default_sweep();
    geometries.push(SweepGeometry::new(4 * 1024, 1));
    geometries.push(SweepGeometry::new(24 * 1024, 3));

    let mut prof = StackProfiler::new(&geometries);
    for &l in &lines {
        prof.access_line(l);
    }

    for &g in &geometries {
        let (accesses, misses) = packed_cache_misses(&lines, g);
        assert_eq!(accesses, prof.accesses());
        assert_eq!(misses, prof.misses_for(g), "synthetic stream @ {g}");
    }

    // and the derived curves agree with the point queries
    for c in prof.curves() {
        assert_eq!(c.misses, prof.misses_for(c.geometry));
        assert_eq!(c.accesses, prof.accesses());
    }
}
