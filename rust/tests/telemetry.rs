//! Telemetry-spine integration tests: the process-global collector
//! (`util::telemetry`) driven through the real replay stack, and the
//! CLI surface that installs it. The contracts under test:
//!
//! - **Inertness** — arming telemetry changes nothing: grid `Metrics`
//!   stay bit-identical, the results JSON stays byte-identical, and
//!   cell fingerprints never see the telemetry state.
//! - **Exporter well-formedness** — the Chrome trace built from a real
//!   multi-threaded grid snapshot parses and keeps per-lane stack
//!   discipline (balanced B/E, non-decreasing timestamps).
//! - **Counter exactness** — the deterministic counters reconcile with
//!   simulator ground truth: `blocks_decoded` equals the trace's block
//!   count, `ledger_hit` equals `cached_cells`.
//! - **Chaos composition** — fault injection and telemetry arm
//!   together; the summary records which faults fired while metrics
//!   stay bit-identical under a retried transient.
//!
//! The collector is process-global, so every test that installs one
//! (or that needs a telemetry-off reference) serializes through
//! [`telemetry_lock`] and disarms via the panic-safe [`Collector`]
//! guard — the same discipline `tests/chaos.rs` uses for fault plans.

use std::process::Command;
use std::sync::{Mutex, MutexGuard};

use mlperf::coordinator::{record_characterize, replay_file, run_jobs_ledgered, run_jobs_replayed};
use mlperf::coordinator::{Job, Scenario};
use mlperf::ledger::{cell_fingerprint, GridResults, Ledger};
use mlperf::obs::{chrome, summary};
use mlperf::util::fault::{self, FaultPlan};
use mlperf::util::json::Json;
use mlperf::util::telemetry::{self, Counter};

mod common;

/// Serialize tests that touch the process-global collector (or that
/// need a telemetry-off reference run).
fn telemetry_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Installs a collector for one scope and uninstalls on drop — even
/// when an assertion panics mid-test, the next test starts disarmed.
struct Collector;

impl Collector {
    fn new(tag: &str) -> Collector {
        telemetry::install(Some(std::env::temp_dir().join("mlperf-telemetry-tests").join(tag)));
        Collector
    }
}

impl Drop for Collector {
    fn drop(&mut self) {
        telemetry::install(None);
    }
}

/// Arms a chaos plan for one scope (see `tests/chaos.rs`).
struct Chaos;

impl Chaos {
    fn new(spec: &str) -> Chaos {
        fault::install(Some(FaultPlan::parse(spec).expect("chaos spec must parse")));
        Chaos
    }
}

impl Drop for Chaos {
    fn drop(&mut self) {
        fault::install(None);
    }
}

fn bin() -> Command {
    let mut c = Command::new(env!("CARGO_BIN_EXE_mlperf"));
    // the spawned CLI must only see what the test passes explicitly
    c.env_remove("MLPERF_CHAOS");
    c.env_remove("MLPERF_TELEMETRY");
    c
}

/// `grid --sweep cache` on one workload: the cheapest real CLI grid.
fn sweep_cmd() -> Command {
    let mut c = bin();
    c.args(["grid", "--sweep", "cache", "--workload", "KMeans"]);
    c.args(["--scale", "0.02", "--iterations", "1", "--threads", "1"]);
    c
}

/// Walk a Chrome trace document and assert per-lane stack discipline:
/// every `E` closes the innermost open `B` on its lane, nothing stays
/// open, and timestamps never run backwards along a lane. Returns the
/// number of B/E pairs walked.
fn assert_wellformed_chrome(doc: &Json) -> usize {
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    let mut stacks: std::collections::BTreeMap<u64, Vec<String>> = Default::default();
    let mut last_ts: std::collections::BTreeMap<u64, f64> = Default::default();
    let mut begins = 0usize;
    let mut ends = 0usize;
    for ev in events {
        let ph = ev.get("ph").and_then(Json::as_str).expect("event phase");
        if ph == "M" {
            continue;
        }
        let tid = ev.get("tid").and_then(Json::as_f64).expect("event tid") as u64;
        let ts = ev.get("ts").and_then(Json::as_f64).expect("event ts");
        let prev = last_ts.entry(tid).or_insert(f64::MIN);
        assert!(ts >= *prev, "lane {tid}: timestamps ran backwards");
        *prev = ts;
        let name = ev.get("name").and_then(Json::as_str).expect("event name").to_string();
        let stack = stacks.entry(tid).or_default();
        match ph {
            "B" => {
                stack.push(name);
                begins += 1;
            }
            "E" => {
                assert_eq!(
                    stack.pop().as_deref(),
                    Some(name.as_str()),
                    "E must close the innermost open B"
                );
                ends += 1;
            }
            other => panic!("unexpected phase {other:?}"),
        }
    }
    assert_eq!(begins, ends, "unbalanced B/E");
    assert!(stacks.values().all(Vec::is_empty), "span left open at end of trace");
    begins
}

/// Arming the collector must change nothing observable: `Metrics`
/// bit-identical, results JSON byte-identical, fingerprints untouched.
#[test]
fn armed_grid_is_bit_identical_to_off() {
    let _lock = telemetry_lock();
    let cfg = common::tiny();
    let jobs = common::scenario_jobs();
    telemetry::install(None);
    let fp_off: Vec<String> =
        jobs.iter().map(|j| cell_fingerprint(&cfg, j).to_string()).collect();
    let clean = run_jobs_replayed(&cfg, &jobs, 1);
    assert!(clean.failed.is_empty());
    let clean_json = GridResults::from_outputs(&cfg, &clean.outputs).to_json();

    let collector = Collector::new("parity");
    let fp_on: Vec<String> =
        jobs.iter().map(|j| cell_fingerprint(&cfg, j).to_string()).collect();
    let armed = run_jobs_replayed(&cfg, &jobs, 1);
    let armed_json = GridResults::from_outputs(&cfg, &armed.outputs).to_json();
    drop(collector);

    assert_eq!(fp_off, fp_on, "telemetry state leaked into fingerprints");
    assert!(armed.failed.is_empty());
    assert_eq!(clean.outputs.len(), armed.outputs.len());
    for (a, b) in clean.outputs.iter().zip(&armed.outputs) {
        assert_eq!(a.job, b.job);
        common::assert_metrics_eq(&a.metrics, &b.metrics, "arming telemetry perturbed the grid");
        assert_eq!(a.quality, b.quality);
    }
    assert_eq!(clean_json, armed_json, "results JSON must be byte-identical");
}

/// A real multi-threaded grid snapshot renders to a parseable Chrome
/// trace with exact stack discipline, and the summary accounts for
/// every cell.
#[test]
fn grid_snapshot_exports_wellformed_trace_and_summary() {
    let _lock = telemetry_lock();
    let cfg = common::tiny();
    let jobs = common::scenario_jobs();
    let collector = Collector::new("chrome");
    let report = run_jobs_replayed(&cfg, &jobs, 2);
    let snap = telemetry::snapshot().expect("collector armed");
    drop(collector);
    assert!(report.failed.is_empty());

    // every grid cell left exactly one outcome row, all healthy
    assert_eq!(snap.cells.len(), jobs.len());
    assert!(snap.cells.iter().all(|c| c.status == "run"));
    assert!(
        snap.cells.iter().all(|c| c.fingerprint.starts_with('v')),
        "cell rows must carry ledger fingerprints"
    );
    // the four KMeans scenario cells ride broadcast batches
    assert_eq!(snap.counter("batch_width_sum"), 4);
    assert!(snap.counter("batches") >= 1);
    assert!(snap.counter("batch_width_max") <= 4);
    assert_eq!(snap.counter("spans_dropped"), 0);

    let doc = chrome::chrome_trace(&snap);
    let parsed = Json::parse(&doc.render()).expect("chrome trace must self-parse");
    let pairs = assert_wellformed_chrome(&parsed);
    assert_eq!(pairs, snap.spans.len(), "one B/E pair per recorded span");
    assert!(pairs > 0, "a grid run must record spans");

    let sum = Json::parse(&summary::summary_json(&snap).render()).expect("summary must parse");
    assert_eq!(sum.get("schema").and_then(Json::as_str), Some("mlperf-telemetry/v1"));
    let cells = sum.get("cells").and_then(Json::as_arr).expect("cells array");
    assert_eq!(cells.len(), jobs.len());
    let stages = sum.get("stages").and_then(Json::as_arr).expect("stages array");
    let stage_count = |name: &str| {
        stages
            .iter()
            .find(|s| s.get("stage").and_then(Json::as_str) == Some(name))
            .and_then(|s| s.get("count").and_then(Json::as_f64))
            .unwrap_or(0.0)
    };
    assert!(stage_count("capture") >= 1.0, "KMeans capture span missing");
    assert!(stage_count("cell-run") >= 3.0, "batch + direct cell spans missing");
}

/// `blocks_decoded` counts each pipelined-ingest block exactly once:
/// it must equal the replay's own block count.
#[test]
fn pipelined_ingest_counts_blocks_exactly() {
    let _lock = telemetry_lock();
    let mut cfg = common::tiny();
    cfg.ingest_threads = 3; // force the staged I/O -> decode pool path
    let w = common::workload("KMeans");
    let path = common::tmpfile("telemetry", "kmeans_blocks.mlt");
    record_characterize(w.as_ref(), &cfg, false, &path).unwrap();

    let collector = Collector::new("blocks");
    let (_, _, stats) = replay_file(&path, &cfg, |_| {}).unwrap();
    let decoded = telemetry::counter(Counter::BlocksDecoded);
    let snap = telemetry::snapshot().expect("collector armed");
    drop(collector);

    assert!(stats.blocks > 0, "trivial trace");
    assert_eq!(decoded, stats.blocks, "blocks_decoded must equal the trace's block count");
    assert_eq!(snap.counter("blocks_decoded"), stats.blocks);
    // every block ran through the decoder pool and the in-order consumer
    assert_eq!(snap.counter("pool_hit") + snap.counter("pool_miss"), stats.blocks);
    let decode_spans = snap
        .stages
        .iter()
        .find(|&&(n, _, _)| n == "decode")
        .map(|&(_, _, c)| c)
        .unwrap_or(0);
    assert_eq!(decode_spans, stats.blocks, "one decode span per block");
}

/// `ledger_hit` equals `cached_cells` by construction, and the cached
/// cells' telemetry rows carry the exact ledger fingerprints.
#[test]
fn ledger_hits_match_cached_cells() {
    let _lock = telemetry_lock();
    let cfg = common::tiny();
    let jobs =
        vec![Job::new("KMeans", Scenario::Baseline), Job::new("KMeans", Scenario::PerfectL2)];
    let path = common::tmpfile("telemetry", "ledger_hits.mllg");
    telemetry::install(None);
    {
        let mut ledger = Ledger::open(&path).unwrap();
        let cold = run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
        assert_eq!(cold.cached_cells, 0);
        assert!(cold.failed.is_empty());
    }

    let collector = Collector::new("ledger");
    let mut ledger = Ledger::open(&path).unwrap();
    let warm = run_jobs_ledgered(&cfg, &jobs, 1, &mut ledger).unwrap();
    let hits = telemetry::counter(Counter::LedgerHit);
    let snap = telemetry::snapshot().expect("collector armed");
    drop(collector);

    assert_eq!(warm.cached_cells, jobs.len(), "warm ledger must serve every cell");
    assert_eq!(warm.workload_executions, 0);
    assert_eq!(hits as usize, warm.cached_cells, "ledger_hit must equal cached_cells");

    let cached: Vec<_> = snap.cells.iter().filter(|c| c.status == "cached").collect();
    assert_eq!(cached.len(), jobs.len());
    for (row, job) in cached.iter().zip(&jobs) {
        assert_eq!(row.fingerprint, cell_fingerprint(&cfg, job).to_string());
        assert_eq!(row.workload, job.workload);
    }
    // ledger open + per-cell lookups leave ledger-open spans behind
    let ledger_opens = snap
        .stages
        .iter()
        .find(|&&(n, _, _)| n == "ledger-open")
        .map(|&(_, _, c)| c)
        .unwrap_or(0);
    assert!(ledger_opens >= 1, "ledger open span missing");
}

/// Chaos and telemetry arm together: a retried transient stall leaves
/// metrics bit-identical while the summary records the fired fault.
#[test]
fn chaos_and_telemetry_compose() {
    let _lock = telemetry_lock();
    let mut cfg = common::tiny();
    cfg.ingest_threads = 3;
    let w = common::workload("KMeans");
    let path = common::tmpfile("telemetry", "kmeans_chaos.mlt");
    record_characterize(w.as_ref(), &cfg, false, &path).unwrap();
    telemetry::install(None);
    fault::install(None);
    let (_, clean, _) = replay_file(&path, &cfg, |_| {}).unwrap();

    let chaos = Chaos::new("stall@1=5");
    let collector = Collector::new("chaos");
    let (_, stalled, _) = replay_file(&path, &cfg, |_| {}).unwrap();
    let snap = telemetry::snapshot().expect("collector armed");
    // the summary reads live fault fire counts — build it while armed
    let sum = Json::parse(&summary::summary_json(&snap).render()).expect("summary must parse");
    drop(collector);
    drop(chaos);

    common::assert_metrics_eq(&stalled, &clean, "stalled telemetered replay diverged");
    let faults = sum.get("faults").expect("faults object");
    assert_eq!(
        faults.get("stall").and_then(Json::as_f64),
        Some(1.0),
        "fired fault missing from telemetry summary"
    );
}

/// `grid --sweep cache --json -` must pipe clean through a JSON
/// parser: the results artifact owns stdout, tables move to stderr.
#[test]
fn cli_grid_json_stdout_is_machine_readable() {
    let out = sweep_cmd().args(["--json", "-"]).output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "sweep failed: {stderr}");
    let parsed = Json::parse(&stdout)
        .unwrap_or_else(|e| panic!("stdout is not pure JSON ({e:?}): {stdout}"));
    assert_eq!(parsed.get("schema").and_then(Json::as_str), Some("mlperf-cache-sweep/v1"));
    assert!(!stdout.contains("=="), "table leaked onto stdout: {stdout}");
    assert!(stderr.contains("cache_sweep"), "table missing from stderr: {stderr}");
}

/// `--telemetry <dir>` (and the `MLPERF_TELEMETRY` env var) write a
/// parseable summary + Chrome trace next to the run.
#[test]
fn cli_telemetry_writes_parseable_artifacts() {
    let dir = std::env::temp_dir().join("mlperf-telemetry-tests").join("cli-artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    let ledger = common::tmpfile("telemetry", "cli_artifacts.mllg");
    let mut cmd = sweep_cmd();
    cmd.args(["--ledger"]).arg(&ledger);
    cmd.arg("--telemetry").arg(&dir);
    let out = cmd.output().unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "telemetered sweep failed: {stderr}");
    assert!(stderr.contains("telemetry: wrote"), "artifact note missing: {stderr}");

    let sum_txt = std::fs::read_to_string(dir.join("telemetry.json")).unwrap();
    let sum = Json::parse(&sum_txt).expect("telemetry.json must parse");
    assert_eq!(sum.get("schema").and_then(Json::as_str), Some("mlperf-telemetry/v1"));
    assert!(sum.get("wall_nanos").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
    let stages = sum.get("stages").and_then(Json::as_arr).expect("stages array");
    let stage_count = |name: &str| {
        stages
            .iter()
            .find(|s| s.get("stage").and_then(Json::as_str) == Some(name))
            .and_then(|s| s.get("count").and_then(Json::as_f64))
            .unwrap_or(0.0)
    };
    assert_eq!(stage_count("sweep-cell"), 1.0, "one sweep span per workload");
    assert!(stage_count("ledger-append") >= 1.0, "ledgered cells must append");
    let prov = sum.get("provenance").expect("provenance block");
    assert!(prov.get("rustc").and_then(Json::as_str).is_some());

    let trace_txt = std::fs::read_to_string(dir.join("telemetry_trace.json")).unwrap();
    let trace = Json::parse(&trace_txt).expect("telemetry_trace.json must parse");
    assert!(assert_wellformed_chrome(&trace) > 0, "trace must contain spans");

    // same artifacts via the environment variable, no flag
    let dir2 = std::env::temp_dir().join("mlperf-telemetry-tests").join("cli-artifacts-env");
    let _ = std::fs::remove_dir_all(&dir2);
    let out = sweep_cmd().env("MLPERF_TELEMETRY", &dir2).output().unwrap();
    assert!(out.status.success());
    assert!(dir2.join("telemetry.json").exists(), "env-var install missing summary");
    assert!(dir2.join("telemetry_trace.json").exists(), "env-var install missing trace");
}
